"""Pure-JAX flash attention with a FlashAttention-2-style custom VJP.

Why this exists: differentiating the online-softmax KV scan with plain
reverse mode makes JAX save every per-block probability matrix — the full
(T, S) attention matrix in fp32, per layer (6+ GB at 4k x 4k per device).
The custom VJP saves only (O, LSE) and *recomputes* the probability blocks
during the backward, exactly like the FlashAttention-2 backward:

  pass dQ : for each Q block, scan KV blocks:  p = exp(s - lse)
            ds = p * (dO v^T - delta);  dq += ds k
  pass dKV: for each KV block, scan Q blocks:  dv += p^T dO;
            dk += ds^T q

Peak live memory is one (block_q x block_k) tile per head group.

Positions/window are traced tensor arguments (per-layer windows inside a
scanned stack) with float0 cotangents.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

NEG = -1e30


def _mask(qp, kp, window, causal: bool):
    """(bq, bk) boolean visibility."""
    dq = qp[:, None]
    dk = kp[None, :]
    ok = dk != jnp.iinfo(jnp.int32).max
    if causal:
        ok &= dk <= dq
    w = jnp.asarray(window, jnp.int32)
    ok &= (w <= 0) | (dq - dk < w)
    return ok


def _pad_to(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def flash_attention_jnp(q, k, v, q_pos, kv_pos, window,
                        causal: bool = True, q_block: int = 1024,
                        kv_chunk: int = 1024, bands=None):
    """q: (B,T,H,D); k/v: (B,S,KV,D) -> (B,T,H,D).

    ``bands``: optional static per-Q-block KV-chunk ranges, from
    :func:`block_bounds` — skips masked-out blocks entirely (diagonal
    skipping for causal self-attention, banding for static sliding
    windows).  Requires ALIGNED positions (q_pos == kv_pos == arange).
    """
    o, _ = _flash_fwd_impl(q, k, v, q_pos, kv_pos, window, causal, q_block,
                           kv_chunk, bands)
    return o


def block_bounds(t: int, s: int, *, causal: bool, window: int,
                 q_block: int, kv_chunk: int):
    """Static per-Q-block [lo, hi) KV-chunk ranges for aligned causal
    self-attention (q_pos == kv_pos == arange(t), t == s).

    Returns a tuple of (lo, hi) per Q block — hashable, so it is usable as
    a nondiff argument of the custom_vjp.
    """
    tp = t + (-t) % q_block
    sp = s + (-s) % kv_chunk
    nq, nk = tp // q_block, sp // kv_chunk
    out = []
    for i in range(nq):
        q_lo, q_hi = i * q_block, min((i + 1) * q_block, t) - 1
        hi = min(nk, -(-(q_hi + 1) // kv_chunk)) if causal else nk
        if window and window > 0:
            lo = max(0, (q_lo - window + 1) // kv_chunk)
        else:
            lo = 0
        out.append((lo, max(hi, lo + 1)))
    return tuple(out)


def _group(q, k, v, q_pos, kv_pos, q_block, kv_chunk):
    b, t, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = jnp.moveaxis(q.reshape(b, t, kvh, g, d), 1, 3)   # (b,kvh,g,t,d)
    kg = jnp.moveaxis(k, 1, 2)                            # (b,kvh,s,d)
    vg = jnp.moveaxis(v, 1, 2)
    qg = _pad_to(qg.astype(jnp.float32), q_block, 3)
    kg = _pad_to(kg.astype(jnp.float32), kv_chunk, 2)
    vg = _pad_to(vg.astype(jnp.float32), kv_chunk, 2)
    qp = _pad_to(q_pos.astype(jnp.int32), q_block, 0,
                 value=jnp.iinfo(jnp.int32).min + 1)
    kp = _pad_to(kv_pos.astype(jnp.int32), kv_chunk, 0,
                 value=jnp.iinfo(jnp.int32).max)
    return qg, kg, vg, qp, kp, (b, t, h, d, s, kvh, g)


def _flash_fwd_impl(q, k, v, q_pos, kv_pos, window, causal, q_block,
                    kv_chunk, bands=None):
    qg, kg, vg, qp, kp, (b, t, h, d, s, kvh, g) = _group(
        q, k, v, q_pos, kv_pos, q_block, kv_chunk)
    scale = 1.0 / np.sqrt(d)
    tp, sp = qg.shape[3], kg.shape[2]
    nq, nk = tp // q_block, sp // kv_chunk
    kc = kg.reshape(b, kvh, nk, kv_chunk, d)
    vc = vg.reshape(b, kvh, nk, kv_chunk, d)
    pc = kp.reshape(nk, kv_chunk)

    def q_block_fn(qb, qpb, lo=0, hi=None):
        hi = nk if hi is None else hi      # (b,kvh,g,bq,d), (bq,)

        def kv_step(carry, inp):
            m, l, acc = carry
            kb, vb, pb = inp               # (b,kvh,bk,d), ..., (bk,)
            sblk = jnp.einsum("bkgtd,bksd->bkgts", qb, kb) * scale
            ok = _mask(qpb, pb, window, causal)
            sblk = jnp.where(ok[None, None, None], sblk, NEG)
            m_new = jnp.maximum(m, sblk.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(sblk - m_new[..., None])
            p = jnp.where(ok[None, None, None], p, 0.0)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgts,bksd->bkgtd", p, vb)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_block, d), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kc[:, :, lo:hi], 2, 0),
             jnp.moveaxis(vc[:, :, lo:hi], 2, 0), pc[lo:hi]))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 1e30)
        return o, lse

    qs_stacked = qg.reshape(b, kvh, g, nq, q_block, d)
    qp_blocks = qp.reshape(nq, q_block)
    if bands is not None:
        # static block skipping: unroll Q blocks with per-block KV ranges
        outs = [q_block_fn(qs_stacked[:, :, :, i], qp_blocks[i],
                           bands[i][0], bands[i][1]) for i in range(nq)]
        o_blocks = jnp.stack([o_ for o_, _ in outs], axis=0)
        lse_blocks = jnp.stack([l_ for _, l_ in outs], axis=0)
    else:
        qs = jnp.moveaxis(qs_stacked, 3, 0)
        o_blocks, lse_blocks = lax.map(
            lambda args: q_block_fn(args[0], args[1]), (qs, qp_blocks))
    o = jnp.moveaxis(o_blocks, 0, 3).reshape(b, kvh, g, tp, d)[:, :, :, :t]
    lse = jnp.moveaxis(lse_blocks, 0, 3).reshape(b, kvh, g, tp)[:, :, :, :t]
    o_out = jnp.moveaxis(o, 3, 1).reshape(b, t, h, d).astype(q.dtype)
    return o_out, lse


def _flash_fwd(q, k, v, q_pos, kv_pos, window, causal, q_block, kv_chunk,
               bands=None):
    o, lse = _flash_fwd_impl(q, k, v, q_pos, kv_pos, window, causal,
                             q_block, kv_chunk, bands)
    return o, (q, k, v, q_pos, kv_pos, window, o, lse)


def _flash_bwd(causal, q_block, kv_chunk, bands, res, do):
    q, k, v, q_pos, kv_pos, window, o, lse = res
    qg, kg, vg, qp, kp, (b, t, h, d, s, kvh, g) = _group(
        q, k, v, q_pos, kv_pos, q_block, kv_chunk)
    scale = 1.0 / np.sqrt(d)
    tp, sp = qg.shape[3], kg.shape[2]
    nq, nk = tp // q_block, sp // kv_chunk

    dog = jnp.moveaxis(do.reshape(b, t, kvh, g, d), 1, 3).astype(jnp.float32)
    og = jnp.moveaxis(o.reshape(b, t, kvh, g, d), 1, 3).astype(jnp.float32)
    delta = jnp.sum(dog * og, axis=-1)                    # (b,kvh,g,t)
    dog = _pad_to(dog, q_block, 3)
    delta_p = _pad_to(delta, q_block, 3)
    lse_p = _pad_to(lse, q_block, 3, value=1e30)

    q_blocks = jnp.moveaxis(qg.reshape(b, kvh, g, nq, q_block, d), 3, 0)
    do_blocks = jnp.moveaxis(dog.reshape(b, kvh, g, nq, q_block, d), 3, 0)
    lse_blocks = jnp.moveaxis(lse_p.reshape(b, kvh, g, nq, q_block), 3, 0)
    dl_blocks = jnp.moveaxis(delta_p.reshape(b, kvh, g, nq, q_block), 3, 0)
    qp_blocks = qp.reshape(nq, q_block)
    k_chunks = jnp.moveaxis(kg.reshape(b, kvh, nk, kv_chunk, d), 2, 0)
    v_chunks = jnp.moveaxis(vg.reshape(b, kvh, nk, kv_chunk, d), 2, 0)
    kp_chunks = kp.reshape(nk, kv_chunk)

    def p_of(qb, kb, qpb, pb, lse_b):
        sblk = jnp.einsum("bkgtd,bksd->bkgts", qb, kb) * scale
        ok = _mask(qpb, pb, window, causal)
        p = jnp.exp(sblk - lse_b[..., None])
        return jnp.where(ok[None, None, None], p, 0.0)

    # ---- pass 1: dQ (outer over Q blocks, scan KV chunks) -----------------
    def dq_block(qb, dob, lse_b, dl_b, qpb, lo=0, hi=None):
        hi = nk if hi is None else hi

        def kv_step(dq, inp):
            kb, vb, pb = inp
            p = p_of(qb, kb, qpb, pb, lse_b)
            dp = jnp.einsum("bkgtd,bksd->bkgts", dob, vb)
            ds = p * (dp - dl_b[..., None])
            return dq + jnp.einsum("bkgts,bksd->bkgtd", ds, kb) * scale, None

        dq0 = jnp.zeros_like(qb)
        dq, _ = lax.scan(kv_step, dq0, (k_chunks[lo:hi], v_chunks[lo:hi],
                                        kp_chunks[lo:hi]))
        return dq

    if bands is not None:
        dq_blocks = jnp.stack([
            dq_block(q_blocks[i], do_blocks[i], lse_blocks[i], dl_blocks[i],
                     qp_blocks[i], bands[i][0], bands[i][1])
            for i in range(nq)], axis=0)
    else:
        dq_blocks = lax.map(
            lambda a: dq_block(*a),
            (q_blocks, do_blocks, lse_blocks, dl_blocks, qp_blocks))
    dqg = jnp.moveaxis(dq_blocks, 0, 3).reshape(b, kvh, g, tp, d)[:, :, :, :t]

    # ---- pass 2: dK, dV (outer over KV chunks, scan Q blocks) -------------
    def dkv_chunk(kb, vb, pb, q_sel=None):
        xs = ((q_blocks, do_blocks, lse_blocks, dl_blocks, qp_blocks)
              if q_sel is None else
              tuple(a[q_sel[0]:q_sel[1]] for a in
                    (q_blocks, do_blocks, lse_blocks, dl_blocks, qp_blocks)))

        def q_step(carry, inp):
            dk, dv = carry
            qb, dob, lse_b, dl_b, qpb = inp
            p = p_of(qb, kb, qpb, pb, lse_b)
            dv = dv + jnp.einsum("bkgts,bkgtd->bksd", p, dob)
            dp = jnp.einsum("bkgtd,bksd->bkgts", dob, vb)
            ds = p * (dp - dl_b[..., None])
            dk = dk + jnp.einsum("bkgts,bkgtd->bksd", ds, qb) * scale
            return (dk, dv), None

        zk = jnp.zeros_like(kb)
        (dk, dv), _ = lax.scan(q_step, (zk, zk), xs)
        return dk, dv

    if bands is not None:
        # invert the bands: KV chunk j is visible to Q blocks i whose band
        # [lo_i, hi_i) contains j.
        qsel = []
        for j in range(nk):
            i_in = [i for i in range(nq) if bands[i][0] <= j < bands[i][1]]
            qsel.append((min(i_in), max(i_in) + 1) if i_in else (0, 0))
        dks, dvs = [], []
        for j in range(nk):
            if qsel[j][0] == qsel[j][1]:
                dks.append(jnp.zeros_like(k_chunks[j]))
                dvs.append(jnp.zeros_like(v_chunks[j]))
            else:
                dk_j, dv_j = dkv_chunk(k_chunks[j], v_chunks[j],
                                       kp_chunks[j], qsel[j])
                dks.append(dk_j)
                dvs.append(dv_j)
        dk_chunks, dv_chunks = jnp.stack(dks, 0), jnp.stack(dvs, 0)
    else:
        dk_chunks, dv_chunks = lax.map(
            lambda a: dkv_chunk(*a), (k_chunks, v_chunks, kp_chunks))
    dkg = jnp.moveaxis(dk_chunks, 0, 2).reshape(b, kvh, sp, d)[:, :, :s]
    dvg = jnp.moveaxis(dv_chunks, 0, 2).reshape(b, kvh, sp, d)[:, :, :s]

    dq = jnp.moveaxis(dqg, 3, 1).reshape(b, t, h, d).astype(q.dtype)
    dk = jnp.moveaxis(dkg, 2, 1).astype(k.dtype)
    dv = jnp.moveaxis(dvg, 2, 1).astype(v.dtype)
    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    return dq, dk, dv, f0(q_pos), f0(kv_pos), f0(window)


flash_attention_jnp.defvjp(_flash_fwd, _flash_bwd)
