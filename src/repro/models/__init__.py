"""Model zoo: configs, layers, and the assembled architectures."""
from .config import (ATTN, ATTN_CROSS, HYMBA, MLSTM, SLSTM, LONG_CONTEXT_OK,
                     SHAPES, ModelConfig, ShapeConfig, cell_is_applicable,
                     get_config, list_archs, register)
from .layers import AxisRules, NO_SHARD
from .transformer import (build_runs, cross_entropy, decode_step,
                          forward_train, init_caches, init_params, prefill)
