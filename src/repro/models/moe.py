"""Token-choice top-k MoE with LACIN expert-parallel dispatch.

The expert-parallel (EP) path is the paper's technique made first-class:
expert shards live on the "model" mesh axis (a radix-16 XOR CIN in the
production HyperX, §5), and the dispatch/combine all-to-alls execute as a
LACIN 1-factor step schedule via the mesh-aware
``repro.fabric.LacinCollectives`` (shard count read from the mesh axis) —
every step a perfect matching, single-hop, contention-free.

Pipeline (per DP shard, fully inside a manual ``shard_map``):

  router top-k -> capacity-bucketed sort-based dispatch (E, C, d)
  -> reshape (n_shards, E_loc*C, d) -> LACIN all-to-all ("model")
  -> expert FFN, batched einsum over local experts
  -> LACIN all-to-all back -> gate-weighted combine (+ dropped-token zeros)

``moe_impl='dense'`` runs the same math without the a2a (single shard) —
used on 1-device smoke tests and as the no-EP baseline.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.fabric import LacinCollectives
from repro._compat.jaxapi import shard_map
from .layers import AxisRules, dense_init


def expert_store_count(cfg) -> int:
    """Experts as stored: padded to a multiple of ``expert_pad_to`` so the
    store shards evenly over the EP axis (granite: 40 -> 48)."""
    pad = max(cfg.expert_pad_to, 1)
    return -(-cfg.num_experts // pad) * pad


def init_moe(key, cfg, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    e = expert_store_count(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, cfg.num_experts), dtype),
        "wi": dense_init(ks[1], (e, d, f), dtype, fan_in=d),
        "wo": dense_init(ks[2], (e, f, d), dtype, fan_in=f),
    }
    if cfg.mlp in ("swiglu", "geglu"):
        p["wg"] = dense_init(ks[3], (e, d, f), dtype, fan_in=d)
    return p


def _capacity(tokens: int, cfg) -> int:
    c = int(np.ceil(tokens * cfg.top_k / cfg.num_experts * cfg.capacity_factor))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def _dispatch_indices(eidx, num_experts: int, capacity: int):
    """Sort-based capacity bucketing.

    eidx: (N,) int32 expert choice per assignment.  Returns (slot (N,),
    valid (N,)): position ``e*C + rank`` for assignments that fit.
    """
    n = eidx.shape[0]
    sort_idx = jnp.argsort(eidx, stable=True)
    sorted_e = eidx[sort_idx]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(num_experts),
                                 side="left")
    ranks_sorted = jnp.arange(n) - seg_start[sorted_e]
    ranks = jnp.zeros((n,), jnp.int32).at[sort_idx].set(
        ranks_sorted.astype(jnp.int32))
    valid = ranks < capacity
    slot = jnp.where(valid, eidx * capacity + ranks, num_experts * capacity)
    return slot, valid


def _expert_ffn(p, x, cfg):
    """x: (E_loc, Cap, d) -> (E_loc, Cap, d), batched over local experts."""
    h = jnp.einsum("ecd,edf->ecf", x, p["wi"].astype(x.dtype))
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, p["wg"].astype(x.dtype))) * h
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, p["wg"].astype(x.dtype)),
                        approximate=True) * h
    elif cfg.mlp == "squared_relu":
        r = jax.nn.relu(h)
        h = r * r
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))


def _moe_local(p, x, cfg, coll: LacinCollectives | None,
               axis_name: str | None):
    """The per-device MoE body.  x: (Tloc, d) local tokens.

    ``coll`` is the mesh-bound LACIN collective set (None = dense / single
    shard); the EP shard count comes from the mesh axis it is bound to,
    so schedule and mesh can never disagree.

    ``p['wi']/['wo']/['wg']`` may be zero-padded along the expert dim so it
    divides ``n_shards`` (e.g. granite's 40 -> 48); the router only ever
    selects real experts, so padding buckets stay empty.
    """
    n_shards = coll.axis_size(axis_name) if coll is not None else 1
    t, d = x.shape
    k = cfg.top_k
    # Bucket count: local expert rows times shards (== padded global count).
    e = p["wi"].shape[0] * n_shards
    e_real = p["router"].shape[1]
    cap = _capacity(t, cfg)

    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)               # (T, E_real)
    gates, eidx = lax.top_k(probs, k)                     # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1).astype(jnp.int32)           # (N=T*k,)
    slot, valid = _dispatch_indices(flat_e, e, cap)
    tok_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(valid[:, None], x[tok_idx], 0))
    buf = buf[:-1]                                        # drop overflow row

    e_loc = e // n_shards
    if n_shards > 1:
        send = buf.reshape(n_shards, e_loc * cap, d)
        recv = coll.all_to_all(send, axis_name)
        # recv[j] = tokens from source shard j for MY local experts
        xin = (recv.reshape(n_shards, e_loc, cap, d)
                   .transpose(1, 0, 2, 3)
                   .reshape(e_loc, n_shards * cap, d))
    else:
        xin = buf.reshape(e_loc, cap, d)

    yout = _expert_ffn(p, xin, cfg)

    if n_shards > 1:
        back = (yout.reshape(e_loc, n_shards, cap, d)
                    .transpose(1, 0, 2, 3)
                    .reshape(n_shards, e_loc * cap, d))
        ret = coll.all_to_all(back, axis_name)
        out_buf = ret.reshape(e * cap, d)
    else:
        out_buf = yout.reshape(e * cap, d)

    picked = jnp.where(valid[:, None],
                       out_buf[jnp.clip(slot, 0, e * cap - 1)], 0)
    y = (picked.reshape(t, k, d) * gates[..., None].astype(x.dtype)).sum(axis=1)

    # Switch-style load-balance aux loss + router z-loss (local stats).
    me = jnp.mean(probs, axis=0)                          # (E_real,)
    ce = (jnp.zeros((e_real,), jnp.float32)
          .at[jnp.clip(flat_e, 0, e_real - 1)].add(1.0) / max(t * k, 1))
    aux = e_real * jnp.sum(me * ce)
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return y, aux, zloss


def apply_moe(p: dict, x, cfg, rules: AxisRules):
    """x: (B, T, d) -> (y, aux_metrics dict).

    EP path runs under a manual shard_map over (dp..., tp); dense path runs
    inline (single shard).
    """
    b, t, d = x.shape
    if cfg.moe_impl == "dense" or rules.tp is None or rules.tp_size == 1:
        y2, aux, z = _moe_local(p, x.reshape(b * t, d), cfg, None, None)
        return y2.reshape(b, t, d), {"moe_aux": aux, "moe_z": z}

    mesh = rules.mesh
    # EP shard count and schedule both come from the mesh axis (the
    # mesh-aware API): no hand-threaded axis_size to disagree with it.
    coll = LacinCollectives(mesh=mesh, instance="auto")
    n_shards = coll.axis_size(rules.tp)
    dp = rules.dp
    manual = set(dp) | {rules.tp}

    # The expert STORE is padded at init (expert_store_count); if it still
    # doesn't divide the EP axis (off-spec config), pad here as a fallback.
    e = p["wi"].shape[0]
    e_pad = -(-e // n_shards) * n_shards
    if e_pad != e:
        padw = [(0, e_pad - e), (0, 0), (0, 0)]
        p = dict(p, wi=jnp.pad(p["wi"], padw), wo=jnp.pad(p["wo"], padw),
                 **({"wg": jnp.pad(p["wg"], padw)} if "wg" in p else {}))

    def body(xl, router, wi, wo, *rest):
        pl = {"router": router, "wi": wi, "wo": wo}
        if rest:
            pl["wg"] = rest[0]
        bl, tl, dl = xl.shape
        y2, aux, z = _moe_local(pl, xl.reshape(bl * tl, dl), cfg, coll,
                                rules.tp)
        aux = lax.pmean(aux, dp) if dp else aux
        z = lax.pmean(z, dp) if dp else z
        return y2.reshape(bl, tl, dl), aux, z

    args = [p["router"], p["wi"], p["wo"]]
    in_specs = [P(dp if dp else None, None, None), P(), P(rules.tp), P(rules.tp)]
    if "wg" in p:
        args.append(p["wg"])
        in_specs.append(P(rules.tp))
    out_specs = (P(dp if dp else None, None, None), P(), P())
    fn = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=out_specs, axis_names=manual,
                       check_vma=False)
    y, aux, z = fn(x, *args)
    return y, {"moe_aux": aux, "moe_z": z}
