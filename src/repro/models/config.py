"""Model & shape configuration for the assigned architecture pool.

Every architecture in the assignment is expressed as a :class:`ModelConfig`;
``src/repro/configs/<arch>.py`` instantiates the exact assigned numbers and
registers it.  Reduced smoke variants derive from the same config via
:meth:`ModelConfig.reduced`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


# Block kinds used in per-layer patterns.  Sliding-window vs full attention
# is NOT a separate kind: it is a per-layer ``windows`` scalar (0 = full),
# so mixed local:global stacks still compile as a single scanned body.
ATTN = "attn"            # (self-)attention + MLP transformer block
ATTN_CROSS = "attn_cross"  # decoder block: self-attn + cross-attn + MLP
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block
HYMBA = "hymba"          # parallel attention ∥ SSM heads + MLP


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # --- block structure -----------------------------------------------------
    block_pattern: tuple[str, ...] = ()   # per-layer kinds; () -> all ATTN
    mlp: str = "swiglu"            # swiglu | geglu | squared_relu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    qk_norm: bool = False
    attn_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- attention -----------------------------------------------------------
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0   # gemma3: separate theta for global layers
    sliding_window: int = 0          # window for SWA layers (windows != 0)
    windows: tuple[int, ...] = ()    # per-layer window; 0 = full attention

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "lacin_ep"       # lacin_ep | dense (no EP comms)
    expert_pad_to: int = 16          # pad expert STORE to a multiple of the
                                     # EP axis (granite: 40 -> 48); router
                                     # never selects padding experts

    # --- SSM / recurrent -----------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    conv_kernel: int = 4
    num_meta_tokens: int = 0         # hymba learnable prefix tokens

    # --- encoder-decoder / frontends ------------------------------------------
    encoder_layers: int = 0
    encoder_seq_len: int = 0         # stub frontend sequence length (frames)
    num_patch_tokens: int = 0        # vlm stub prefix length

    # --- execution knobs (not architecture) -----------------------------------
    vocab_pad_to: int = 16           # pad the embedding/unembedding STORE so
                                     # the vocab dim shards evenly (Megatron-
                                     # style); pad logits are masked to -inf
    # beyond-paper perf knobs (default OFF = paper-faithful baseline):
    attn_skip_diagonal: bool = False  # skip above-diagonal KV blocks (causal)
    attn_banded: bool = False         # band KV blocks for static windows;
                                      # splits mixed-window stacks into
                                      # uniform-window runs
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"              # full | dots | none
    attention_impl: str = "reference"  # reference | pallas
    scan_layers: bool = True
    # decode-time KV layout: "full" keeps seq-len cache on every layer;
    # "windowed" keeps only sliding_window entries for SWA layers.
    swa_cache: str = "full"

    # ---------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if not self.block_pattern:
            object.__setattr__(self, "block_pattern", (ATTN,) * self.num_layers)
        if not self.windows:
            object.__setattr__(self, "windows", (0,) * self.num_layers)
        if len(self.block_pattern) != self.num_layers:
            raise ValueError(
                f"{self.name}: block_pattern has {len(self.block_pattern)} entries "
                f"for {self.num_layers} layers")
        if len(self.windows) != self.num_layers:
            raise ValueError(f"{self.name}: windows must have one entry per layer")
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError(f"{self.name}: num_heads must be divisible by kv heads")

    # -- derived ---------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def vocab_padded(self) -> int:
        pad = max(self.vocab_pad_to, 1)
        return -(-self.vocab_size // pad) * pad

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def kinds(self) -> tuple[str, ...]:
        return self.block_pattern

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, h, kv, dh = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for kind in self.block_pattern:
            if kind in (ATTN, ATTN_CROSS, HYMBA):
                attn = d * dh * (h + 2 * kv) + h * dh * d
                if kind == HYMBA:
                    inner = self.ssm_expand * d
                    attn += (d * inner * 2 + inner * self.conv_kernel
                             + inner * (2 * self.ssm_state + 1) + inner * d)
                total += attn
                if self.is_moe:
                    gated = 3 if self.mlp in ("swiglu", "geglu") else 2
                    total += self.num_experts * gated * d * self.d_ff + d * self.num_experts
                elif self.d_ff:
                    gated = 3 if self.mlp in ("swiglu", "geglu") else 2
                    total += gated * d * self.d_ff
            elif kind == MLSTM:
                inner = self.ssm_expand * d
                total += d * inner * 2              # up gate/val
                total += inner * self.conv_kernel   # depthwise conv
                total += inner * inner * 3          # q, k, v over inner
                total += inner * 3                  # i, f gates + skip scale
                total += inner * d                  # down
            elif kind == SLSTM:
                total += d * d * 4                  # input gates
                total += self.num_heads * (d // self.num_heads) ** 2 * 4  # recurrent
                total += inner_ffn(d)
        if self.is_encdec:
            # encoder blocks (ATTN) + decoder cross-attention
            attn = d * dh * (h + 2 * kv) + h * dh * d
            gated = 3 if self.mlp in ("swiglu", "geglu") else 2
            total += self.encoder_layers * (attn + gated * d * self.d_ff)
            total += self.num_layers * attn       # cross-attn per decoder layer
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of num_experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        gated = 3 if self.mlp in ("swiglu", "geglu") else 2
        moe_total = self.num_layers * self.num_experts * gated * d * self.d_ff
        moe_active = self.num_layers * self.top_k * gated * d * self.d_ff
        return self.param_count() - moe_total + moe_active

    # -- reduced (smoke-test) variant -------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        layers = min(self.num_layers, 4)
        pattern = _reduce_pattern(self.block_pattern, layers)
        kv = min(self.num_kv_heads, 2)   # keep GQA grouping (1 or 2 kv heads)
        heads = 4                        # 4 query heads, q_per_kv = 4 or 2
        wins = [min(w, 8) for w in self.windows[:layers]]
        if 0 in self.windows and any(self.windows) and 0 not in wins:
            wins[-1] = 0  # keep the local:global mix in the reduced config
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=layers,
            block_pattern=pattern,
            windows=tuple(wins),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=min(self.d_ff, 128) if self.d_ff else 0,
            vocab_size=256,
            num_experts=min(self.num_experts, 8) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            expert_pad_to=1,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq_len=min(self.encoder_seq_len, 16),
            num_patch_tokens=min(self.num_patch_tokens, 8),
            num_meta_tokens=min(self.num_meta_tokens, 4),
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            remat="none",
            scan_layers=self.scan_layers,
        )


def inner_ffn(d: int) -> int:
    """sLSTM post-FFN (xLSTM uses a 4/3 gated projection)."""
    ff = int(d * 4 / 3)
    return 3 * d * ff


def _reduce_pattern(pattern: tuple[str, ...], layers: int) -> tuple[str, ...]:
    """Keep the *variety* of block kinds in a shorter pattern."""
    kinds = []
    for k in pattern:
        if k not in kinds:
            kinds.append(k)
    out = list(pattern[:layers])
    # make sure every kind appears at least once
    for idx, k in enumerate(kinds):
        if k not in out and idx < layers:
            out[-(idx + 1)] = k
    return tuple(out)


# ---------------------------------------------------------------------------
# Shapes (assigned): four cells per architecture.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, ShapeConfig] = {s.name: s for s in
                                  (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

#: Architectures with sub-quadratic sequence handling, eligible for the
#: ``long_500k`` cell (others are skipped per the assignment, see DESIGN.md).
LONG_CONTEXT_OK = frozenset({"xlstm-350m", "hymba-1.5b", "gemma3-1b"})


def cell_is_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, ("pure full-attention architecture: 524k-token decode "
                       "needs sub-quadratic attention (DESIGN.md §6)")
    return True, ""


# ---------------------------------------------------------------------------
# Registry (populated by repro.configs modules).
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        import repro.configs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def list_archs() -> list[str]:
    if not _REGISTRY:
        import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
