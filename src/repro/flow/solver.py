"""Progressive-filling max-min fair solver over a flow/link incidence.

The flow model reduces every traffic pattern to a *rate allocation
problem*: flows (CSR lists of directed-link ids) with demands, links
with capacities, and the engine-calibrated question "what rate does each
flow sustain?".  The canonical answer for a work-conserving fabric with
per-flow queues is the **max-min fair** allocation, computed here by
progressive filling (Bertsekas & Gallager §6.5.2):

1. raise every active flow's rate at a common speed;
2. the first constraint to bind is either a link running out of residual
   capacity (its flows are *bottlenecked* — frozen at the current level)
   or a flow reaching its demand (frozen *satisfied*);
3. repeat with the survivors until no flow is active.

Each iteration freezes at least one flow, and symmetric patterns freeze
whole equivalence classes at once, so the loop runs for the number of
distinct bottleneck levels — single digits on every in-repo pattern —
with O(nnz) vectorized work per iteration.

Two interchangeable cores: the numpy reference (default) and an optional
jitted JAX core (``lax.while_loop`` over the same update) for the
largest fabrics.  Both return identical allocations to float tolerance;
``solver="auto"`` picks JAX only when the incidence is big enough to
amortize the compile.
"""
from __future__ import annotations

import numpy as np

__all__ = ["maxmin_rates", "maxmin_rates_numpy", "maxmin_rates_jax"]

#: Residual-capacity slack below which a link counts as saturated.  The
#: filling step subtracts ``inc * n_active`` from the binding link's
#: residual, which lands on 0 up to one rounding error of the division
#: that produced ``inc``; 1e-9 is orders above that for unit capacities.
TOL = 1e-9

#: ``solver="auto"``: incidence size (nonzeros) above which the jitted
#: core is worth its per-shape compile.
JAX_NNZ_THRESHOLD = 2_000_000


def _entry_flow(flow_ptr: np.ndarray) -> np.ndarray:
    """Flow index of every CSR entry."""
    counts = np.diff(flow_ptr)
    return np.repeat(np.arange(counts.size), counts)


def maxmin_rates_numpy(demand: np.ndarray, link_idx: np.ndarray,
                       flow_ptr: np.ndarray, capacity: np.ndarray, *,
                       max_iters: int = 256) -> np.ndarray:
    """Max-min fair rates (numpy reference core).

    ``demand``: (F,) offered rate per flow; ``link_idx``/(``flow_ptr``):
    CSR of each flow's *compacted* link indices (a flow crossing a link
    twice lists it twice and consumes capacity twice); ``capacity``:
    (L,) per-link capacity.  Returns (F,) rates with ``0 <= rate <=
    demand``.
    """
    demand = np.asarray(demand, dtype=np.float64)
    capacity = np.asarray(capacity, dtype=np.float64)
    F, L = demand.size, capacity.size
    entry_flow = _entry_flow(np.asarray(flow_ptr))
    link_idx = np.asarray(link_idx)
    rates = np.zeros(F)
    active = demand > TOL
    resid = capacity.copy()
    for _ in range(max_iters):
        if not active.any():
            break
        ea = active[entry_flow]
        n_act = np.bincount(link_idx[ea], minlength=L).astype(np.float64)
        used = n_act > 0
        alpha = np.min(resid[used] / n_act[used]) if used.any() else np.inf
        beta = np.min(demand[active] - rates[active])
        inc = min(alpha, beta)
        if np.isfinite(inc) and inc > 0:
            rates[active] += inc
            resid -= inc * n_act
            np.maximum(resid, 0.0, out=resid)
        tight = used & (resid <= TOL)
        flow_tight = np.zeros(F, dtype=bool)
        if tight.any():
            hit = ea & tight[link_idx]
            flow_tight[entry_flow[hit]] = True
        met = rates >= demand - TOL
        newly = active & (flow_tight | met)
        if not newly.any():
            # Numerical stall (should not happen: inc==alpha saturates a
            # link, inc==beta satisfies a flow).  Freeze the survivors at
            # their current — already fair — rates rather than spin.
            break
        active &= ~newly
    return rates


def _jax_core(demand, entry_flow, link_idx, capacity, max_iters: int):
    import jax.numpy as jnp
    from jax import lax

    F = demand.shape[0]
    L = capacity.shape[0]

    def cond(state):
        i, _rates, active, _resid = state
        return (i < max_iters) & active.any()

    def body(state):
        i, rates, active, resid = state
        ea = active[entry_flow]
        n_act = jnp.zeros(L).at[link_idx].add(ea.astype(jnp.float64))
        used = n_act > 0
        share = jnp.where(used, resid / jnp.maximum(n_act, 1.0), jnp.inf)
        alpha = jnp.min(share)
        beta = jnp.min(jnp.where(active, demand - rates, jnp.inf))
        inc = jnp.minimum(alpha, beta)
        inc = jnp.where(jnp.isfinite(inc) & (inc > 0), inc, 0.0)
        rates = jnp.where(active, rates + inc, rates)
        resid = jnp.maximum(resid - inc * n_act, 0.0)
        tight = used & (resid <= TOL)
        flow_tight = (jnp.zeros(F, dtype=bool)
                      .at[entry_flow].max(ea & tight[link_idx]))
        met = rates >= demand - TOL
        newly = active & (flow_tight | met)
        # Same stall safeguard as the numpy core: no progress deactivates
        # everything (rates already hold the fair allocation so far).
        active = jnp.where(newly.any(), active & ~newly,
                           jnp.zeros_like(active))
        return i + 1, rates, active, resid

    state = (jnp.int32(0), jnp.zeros(F), demand > TOL,
             jnp.asarray(capacity, jnp.float64))
    _, rates, _, _ = lax.while_loop(cond, body, state)
    return rates


_JIT_CACHE: dict = {}


def maxmin_rates_jax(demand: np.ndarray, link_idx: np.ndarray,
                     flow_ptr: np.ndarray, capacity: np.ndarray, *,
                     max_iters: int = 256) -> np.ndarray:
    """The jitted core: one ``lax.while_loop`` program per incidence
    shape (cached process-wide), bit-compatible semantics with
    :func:`maxmin_rates_numpy` up to float tolerance.

    float64 is scoped with :func:`jax.experimental.enable_x64` rather
    than the global ``jax_enable_x64`` flag so that the int32-typed
    cycle engines sharing the process keep their dtypes."""
    import jax
    import jax.experimental
    key = int(max_iters)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(_jax_core, static_argnums=(4,))
        _JIT_CACHE[key] = fn
    entry_flow = _entry_flow(np.asarray(flow_ptr))
    with jax.experimental.enable_x64():
        out = fn(np.asarray(demand, np.float64), entry_flow,
                 np.asarray(link_idx), np.asarray(capacity, np.float64),
                 max_iters)
    return np.asarray(out)


def maxmin_rates(demand, link_idx, flow_ptr, capacity, *,
                 max_iters: int = 256, solver: str = "auto") -> np.ndarray:
    """Dispatch: ``"numpy"`` | ``"jax"`` | ``"auto"`` (numpy unless the
    incidence is large enough for the jit to pay for itself)."""
    if solver == "numpy":
        return maxmin_rates_numpy(demand, link_idx, flow_ptr, capacity,
                                  max_iters=max_iters)
    if solver == "jax":
        return maxmin_rates_jax(demand, link_idx, flow_ptr, capacity,
                                max_iters=max_iters)
    if solver != "auto":
        raise ValueError(f"unknown flow solver {solver!r}; "
                         f"expected 'numpy', 'jax' or 'auto'")
    if np.asarray(link_idx).size >= JAX_NNZ_THRESHOLD:
        try:
            return maxmin_rates_jax(demand, link_idx, flow_ptr, capacity,
                                    max_iters=max_iters)
        except Exception:       # pragma: no cover - jax is an in-repo dep
            pass
    return maxmin_rates_numpy(demand, link_idx, flow_ptr, capacity,
                              max_iters=max_iters)
