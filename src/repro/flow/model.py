"""Flow-level model: demands, routes, and calibrated link capacities.

This module turns a ``SimTopology`` plus a traffic description into the
three arrays the max-min solver consumes:

* a **demand vector** — one entry per (src, dst[, class]) flow, in
  packets/cycle offered;
* a **route incidence** — each flow's directed-link ids in CSR form,
  traced hop-by-hop with ``minimal_port`` (never the dense O(N²) route
  table, so 10k-switch fabrics stay cheap);
* a **capacity vector** — per directed link, in packets/cycle.

Capacity calibration
--------------------
The cycle engines move at most one packet per directed link per cycle,
so raw capacity is 1.0.  But packets *entering* the fabric contend
differently from packets *crossing* it: each switch serves its T
terminal FIFOs into P output links head-of-line, and transit traffic
has priority.  Under sustained random load the injection stage only
achieves a fraction of link bandwidth — classic HOL behaviour, about
``1 - (1 - 1/P)**T`` ≈ 0.56 for the CIN-16 operating point and measured
at ≈0.55 effective across the bundled oracle sweeps.  We fold this into
the link, not the flow: a link whose demand is a mix of injection
(first-hop) and transit traffic gets

    C_l = ETA_INJECTION ** (injection_demand_l / total_demand_l)

i.e. capacity 1.0 for pure-transit links (the Dragonfly adversarial
oracle's exact ``accepted = 1/8`` plateau requires this) sliding to
``ETA_INJECTION`` for pure-injection links.  One scalar, calibrated
once against the CIN-16 oracle knees and validated on every other
bundled spec — see ``docs/flow_model.md`` for the derivation and the
constraint interval.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.sim.topology import SimTopology

__all__ = [
    "ETA_INJECTION", "FlowParams", "FlowProblem",
    "trace_routes", "trace_routes_via",
    "uniform_demands", "permutation_demands", "hotspot_demands",
    "adversarial_demands", "demands_from_traffic", "link_capacities",
]

#: Injection-stage HOL efficiency: fraction of link bandwidth a
#: saturated injection stage achieves.  Theoretical estimate for the
#: CIN-16 operating point (T=12 FIFOs over P=15 links):
#: ``1-(1-1/15)**12 = 0.563``; the bundled oracle knees constrain the
#: effective value to [0.532, 0.578) and 0.55 sits mid-interval.
ETA_INJECTION = 0.55


@dataclass(frozen=True)
class FlowParams:
    """Knobs of the flow model; defaults reproduce the oracle knees."""
    eta_injection: float = ETA_INJECTION
    #: Above this many (src, dst) pairs, uniform traffic is sampled
    #: rather than enumerated (scale-out guard for 10k+ fabrics).
    max_pairs: int = 100_000
    #: Valiant flows enumerate all n-2 intermediates exactly while
    #: ``flows * (n-2)`` stays under this budget; sampled above it.
    split_budget: int = 500_000
    max_iters: int = 256
    solver: str = "auto"
    #: UGAL-fluid detour rule: a flow leaves the minimal route when its
    #: worst-link utilization exceeds ``detour_weight`` times the fabric
    #: mean (and 1.0); mirrors AdaptivePolicy's weight=2 backlog test.
    detour_weight: float = 2.0
    #: RNG seed for the sampling fallbacks (pair/mid sampling).  The
    #: model itself is deterministic whenever it enumerates exactly.
    sample_seed: int = 0


@dataclass
class FlowProblem:
    """Solver input: flows (demand + CSR routes) over directed links.

    ``link_ids``/``flow_ptr`` follow CSR convention: flow f's route is
    ``link_ids[flow_ptr[f]:flow_ptr[f+1]]``, links as ``switch *
    num_ports + port``.  ``injection`` marks each entry that is a flow's
    first hop (segment-1 first hop only, for Valiant flows).
    """
    demand: np.ndarray       # (F,)
    link_ids: np.ndarray     # (nnz,)
    flow_ptr: np.ndarray     # (F+1,)
    injection: np.ndarray    # (nnz,) bool
    src: np.ndarray          # (F,)
    dst: np.ndarray          # (F,)

    @property
    def num_flows(self) -> int:
        return int(self.demand.size)


def _concat_problems(parts: list[FlowProblem]) -> FlowProblem:
    """Stack independent flow sets into one problem."""
    parts = [p for p in parts if p.num_flows]
    if len(parts) == 1:
        return parts[0]
    ptrs = [parts[0].flow_ptr]
    for p in parts[1:]:
        ptrs.append(p.flow_ptr[1:] + (ptrs[-1][-1] - p.flow_ptr[0]))
    return FlowProblem(
        demand=np.concatenate([p.demand for p in parts]),
        link_ids=np.concatenate([p.link_ids for p in parts]),
        flow_ptr=np.concatenate(ptrs),
        injection=np.concatenate([p.injection for p in parts]),
        src=np.concatenate([p.src for p in parts]),
        dst=np.concatenate([p.dst for p in parts]))


# ---------------------------------------------------------------------------
# Route tracing


def trace_routes(topo: SimTopology, src: np.ndarray,
                 dst: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Minimal routes for each (src[i], dst[i]) pair, CSR-encoded.

    Walks all pairs in lockstep with vectorized ``minimal_port`` calls —
    at most ``topo.diameter`` rounds over flat arrays, no dense route
    table.  Returns ``(link_ids, flow_ptr)``.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    F = src.size
    cur = src.copy()
    hops_f: list[np.ndarray] = []   # flow index per collected hop
    hops_l: list[np.ndarray] = []   # link id per collected hop
    pending = np.arange(F)
    for _ in range(max(topo.diameter, 1) + 1):
        alive = cur[pending] != dst[pending]
        pending = pending[alive]
        if pending.size == 0:
            break
        c = cur[pending]
        port = np.asarray(topo.minimal_port(c, dst[pending]))
        nxt = topo.neighbor[c, port]
        if (nxt < 0).any():
            # Only reachable on degraded fabrics: the fallback table
            # gives port 0 for unreachable pairs and port 0 may be dead.
            # Raise here, by name, rather than let the -1 wrap into a
            # wandering walk that fails the convergence check cryptically.
            bad = nxt < 0
            raise RuntimeError(
                f"route tracing on {topo.name} stepped onto an unwired "
                f"port for {int(bad.sum())} pair(s) (first: switch "
                f"{int(c[bad][0])} -> {int(dst[pending][bad][0])}); on a "
                f"degraded fabric this means the pair is unreachable — "
                f"filter demands with repro.faults.filter_pairs (policy="
                f"'drop') or use a connected FailureSpec")
        hops_f.append(pending.copy())
        hops_l.append(c * topo.num_ports + port)
        cur[pending] = nxt
    else:
        left = pending[cur[pending] != dst[pending]]
        if left.size:
            raise RuntimeError(
                f"minimal routing did not converge within diameter "
                f"{topo.diameter} for {left.size} pairs on {topo.name}")
    if not hops_f:
        return (np.zeros(0, dtype=np.int64),
                np.zeros(F + 1, dtype=np.int64))
    flow_of = np.concatenate(hops_f)
    link_of = np.concatenate(hops_l)
    # Hop-major → flow-major, preserving hop order within each flow
    # (stable sort; hops were appended in walk order).
    order = np.argsort(flow_of, kind="stable")
    counts = np.bincount(flow_of, minlength=F)
    flow_ptr = np.zeros(F + 1, dtype=np.int64)
    np.cumsum(counts, out=flow_ptr[1:])
    return link_of[order], flow_ptr


def trace_routes_via(topo: SimTopology, src: np.ndarray, mid: np.ndarray,
                     dst: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Two-segment (Valiant) routes src→mid→dst as single CSR flows.

    Each flow's entries are segment-1 hops followed by segment-2 hops,
    so the solver sees the full path as one coupled flow.
    """
    l1, p1 = trace_routes(topo, src, mid)
    l2, p2 = trace_routes(topo, mid, dst)
    c1 = np.diff(p1)
    c2 = np.diff(p2)
    total = c1 + c2
    ptr = np.zeros(total.size + 1, dtype=np.int64)
    np.cumsum(total, out=ptr[1:])
    out = np.empty(int(ptr[-1]), dtype=np.int64)
    # Vectorized interleave: per-flow destinations for each segment.
    idx1 = np.repeat(ptr[:-1], c1) + _ranges(c1)
    idx2 = np.repeat(ptr[:-1] + c1, c2) + _ranges(c2)
    out[idx1] = l1
    out[idx2] = l2
    return out, ptr


def _ranges(counts: np.ndarray) -> np.ndarray:
    """``concatenate([arange(c) for c in counts])`` without the loop."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    out = np.ones(total, dtype=np.int64)
    ends = np.cumsum(counts)
    starts = ends - counts
    nz = counts > 0
    out[starts[nz]] = 0
    first = starts[nz][1:]
    out[first] -= (counts[nz][:-1] - 1)
    return np.cumsum(out)


def _injection_mask(flow_ptr: np.ndarray) -> np.ndarray:
    """First entry of every non-empty flow route."""
    mask = np.zeros(int(flow_ptr[-1]), dtype=bool)
    starts = flow_ptr[:-1]
    nonempty = np.diff(flow_ptr) > 0
    mask[starts[nonempty]] = True
    return mask


# ---------------------------------------------------------------------------
# Demand builders (one per declarative traffic pattern)


def _merge_duplicate_pairs(src, dst, rate, n):
    """Sum rates of repeated (src, dst) pairs into unique flows."""
    key = src.astype(np.int64) * n + dst
    uniq, inverse = np.unique(key, return_inverse=True)
    merged = np.bincount(inverse, weights=rate)
    return uniq // n, uniq % n, merged


def uniform_demands(topo: SimTopology, load: float, terminals: int,
                    params: FlowParams):
    """All-to-all uniform: every ordered pair at ``T·o/(n-1)``.

    Exact enumeration while ``n(n-1) <= max_pairs``; above that, pairs
    are sampled with replacement and rates scaled to preserve the total
    offered traffic (the max-min allocation of uniform traffic is
    insensitive to which symmetric subset represents it).
    """
    n = topo.num_switches
    total = n * (n - 1)
    per_pair = terminals * load / max(n - 1, 1)
    if total <= params.max_pairs:
        src = np.repeat(np.arange(n), n - 1)
        # dst enumeration without the O(n^2) python loop: for each src s,
        # dsts are 0..n-1 minus s, via the shift-remap trick.
        k = np.tile(np.arange(n - 1), n)
        dst = k + (k >= np.repeat(np.arange(n), n - 1))
        rate = np.full(total, per_pair)
        return src, dst, rate
    rng = np.random.default_rng(params.sample_seed)
    k = params.max_pairs
    src = rng.integers(0, n, size=k)
    raw = rng.integers(0, n - 1, size=k)
    dst = raw + (raw >= src)
    rate = np.full(k, terminals * load * n / k)
    return _merge_duplicate_pairs(src, dst, rate, n)


def permutation_demands(topo: SimTopology, load: float, terminals: int,
                        params: FlowParams, *, perm=None):
    n = topo.num_switches
    src = np.arange(n)
    dst = np.asarray(perm) if perm is not None else (src + n // 2) % n
    keep = src != dst
    return src[keep], dst[keep], np.full(int(keep.sum()),
                                         float(terminals) * load)


def hotspot_demands(topo: SimTopology, load: float, terminals: int,
                    params: FlowParams, *, hot_fraction: float = 0.8,
                    hot_dst: int | None = None, partner_shift=None):
    """Each switch sends ``hot_fraction`` to a fixed partner (or one
    shared ``hot_dst``) and the rest uniformly — mirrors
    ``sim.traffic.hotspot``'s analytic mix."""
    n = topo.num_switches
    src = np.arange(n)
    if hot_dst is not None:
        hot = np.full(n, int(hot_dst))
    else:
        shift = partner_shift if partner_shift is not None else max(n // 2, 1)
        hot = (src + shift) % n
    hot_rate = np.full(n, terminals * load * hot_fraction)
    u_src, u_dst, u_rate = uniform_demands(topo, load * (1 - hot_fraction),
                                           terminals, params)
    src = np.concatenate([src, u_src])
    dst = np.concatenate([hot, u_dst])
    rate = np.concatenate([hot_rate, u_rate])
    keep = src != dst
    return _merge_duplicate_pairs(src[keep], dst[keep], rate[keep], n)


def adversarial_demands(topo: SimTopology, load: float, terminals: int,
                        params: FlowParams):
    """Dragonfly worst case: group g sends only to group g+1, dst
    uniform over that group's switches — ``g·a²`` exact pairs."""
    cfg = topo.meta.get("config")
    a = cfg.group_size
    g = cfg.num_groups
    grp = np.arange(g)
    src_local = np.arange(a)
    dst_local = np.arange(a)
    src = (grp[:, None, None] * a + src_local[None, :, None])
    dst = ((grp[:, None, None] + 1) % g * a + dst_local[None, None, :])
    src = np.broadcast_to(src, (g, a, a)).ravel()
    dst = np.broadcast_to(dst, (g, a, a)).ravel()
    rate = np.full(src.size, terminals * load / a)
    return src, dst, rate


def demands_from_traffic(traffic, num_switches: int):
    """Empirical demand matrix from a generated ``Traffic`` object —
    the fallback for inline/custom patterns and ``simulate(backend=
    "flow")``: unique (src, dst) pair counts over the horizon."""
    src = np.asarray(traffic.src, dtype=np.int64)
    dst = np.asarray(traffic.dst, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    horizon = max(int(traffic.horizon), 1)
    rate = np.full(src.size, 1.0 / horizon)
    return _merge_duplicate_pairs(src, dst, rate, num_switches)


# ---------------------------------------------------------------------------
# Capacities


def link_capacities(topo: SimTopology, problem: FlowProblem,
                    params: FlowParams) -> np.ndarray:
    """Per-directed-link capacity, injection-share calibrated.

    ``C_l = eta ** (injection_demand_l / total_demand_l)`` — 1.0 for
    pure-transit links, ``eta`` for pure-injection links (see module
    docstring).  Links with no demand get capacity 1.0.
    """
    L = topo.num_switches * topo.num_ports
    entry_rate = np.repeat(problem.demand, np.diff(problem.flow_ptr))
    total = np.bincount(problem.link_ids, weights=entry_rate, minlength=L)
    inj = np.bincount(problem.link_ids[problem.injection],
                      weights=entry_rate[problem.injection], minlength=L)
    share = np.divide(inj, total, out=np.zeros(L), where=total > 0)
    return params.eta_injection ** share
