"""``repro.flow`` — the flow-level fair-share backend.

The third simulation fidelity tier: where the numpy oracle is exact and
the compiled engine is fast, the flow model is *scalable* — an
analytical max-min fair-share model that turns traffic patterns and
collective workloads into flow demand matrices over traced routes,
solves for per-flow rates by progressive filling, and reads saturation
throughput, bottleneck link sets, and replay completion estimates off
the allocation.  10k-switch fabrics resolve in seconds.

Cross-validated against the numpy oracle's knees on every bundled spec
(see ``tests/test_flow.py`` and ``docs/flow_model.md``); reachable via
``simulate(backend="flow")``, ``Study(backend="flow")``,
``Fabric.replay(backend="flow")``, and ``python -m repro.studies run
--backend flow``.
"""
from .adapters import (FlowSolution, ROUTINGS, pattern_demands,
                       replay_estimate, replay_stats, saturation_load, serving_stats,
                       simulate_flow, solve_flows, study_point_stats)
from .model import (ETA_INJECTION, FlowParams, FlowProblem,
                    adversarial_demands, demands_from_traffic,
                    hotspot_demands, link_capacities, permutation_demands,
                    trace_routes, trace_routes_via, uniform_demands)
from .solver import maxmin_rates, maxmin_rates_jax, maxmin_rates_numpy

__all__ = [
    "ETA_INJECTION", "ROUTINGS", "FlowParams", "FlowProblem",
    "FlowSolution",
    "trace_routes", "trace_routes_via",
    "uniform_demands", "permutation_demands", "hotspot_demands",
    "adversarial_demands", "demands_from_traffic", "link_capacities",
    "maxmin_rates", "maxmin_rates_numpy", "maxmin_rates_jax",
    "solve_flows", "pattern_demands", "simulate_flow",
    "study_point_stats", "replay_estimate", "replay_stats",
    "serving_stats",
    "saturation_load",
]
