"""Adapters: the flow model behind the repo's existing seams.

Everything here speaks the vocabulary of the cycle engines —
``SimTopology`` + policy + traffic in, :class:`repro.sim.metrics.RunStats`
out — so the flow backend slots into ``simulate(backend="flow")``,
``Study`` grids, and ``Fabric.replay`` without new call sites.

Three entry points:

* :func:`solve_flows` — the raw model: (src, dst, rate) demands under a
  routing discipline → max-min rates + bottleneck link sets
  (:class:`FlowSolution`);
* :func:`simulate_flow` / :func:`study_point_stats` — RunStats-shaped
  estimates for open-loop saturation grids (analytic demand matrices
  for the declarative patterns, empirical ones for inline traffic);
* :func:`replay_estimate` / :func:`replay_stats` — phase-by-phase
  collective completion bounds (``completion_cycles`` etc.).

Fidelity contract: the flow model predicts *rates and completion*, not
queueing dynamics.  ``accepted``/``saturated``/``completion_cycles``
are cross-validated against the numpy oracle (tests/test_flow.py);
latency fields are hop-count lower-bound proxies and ``link_util_*``
are offered-rate utilizations — present so downstream tables render,
but not knee-comparable across fidelities.  ``Result.fidelity ==
"flow"`` marks every record produced here.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.sim.metrics import RunStats
from repro.sim.topology import SimTopology

from .model import (FlowParams, FlowProblem, _concat_problems,
                    _injection_mask, adversarial_demands,
                    demands_from_traffic, hotspot_demands, link_capacities,
                    permutation_demands, trace_routes, trace_routes_via,
                    uniform_demands)
from .solver import maxmin_rates

__all__ = ["FlowSolution", "solve_flows", "pattern_demands",
           "simulate_flow", "study_point_stats", "replay_estimate",
           "replay_stats", "serving_stats", "saturation_load"]

#: Routing disciplines the flow model understands (the three in-repo
#: policies; anything else must come through inline traffic + minimal).
ROUTINGS = ("minimal", "valiant", "adaptive")


@dataclass
class FlowSolution:
    """A solved flow problem: rates, capacities, and where it binds."""
    topo: SimTopology
    routing: str
    problem: FlowProblem
    capacity: np.ndarray        # (L,) per directed link
    rates: np.ndarray           # (F,) max-min allocation
    params: FlowParams = field(default_factory=FlowParams)

    @property
    def offered_rate(self) -> float:
        """Total offered demand, packets/cycle fabric-wide."""
        return float(self.problem.demand.sum())

    @property
    def delivered_rate(self) -> float:
        """Total max-min throughput, packets/cycle fabric-wide."""
        return float(self.rates.sum())

    @property
    def served(self) -> np.ndarray:
        """Carried rate per directed link (packets/cycle)."""
        L = self.topo.num_switches * self.topo.num_ports
        entry = np.repeat(self.rates, np.diff(self.problem.flow_ptr))
        return np.bincount(self.problem.link_ids, weights=entry, minlength=L)

    def bottleneck_links(self, top: int = 10) -> list[dict]:
        """The ``top`` most-utilized wired links (served/capacity), the
        flow model's answer to "where would this fabric bind first"."""
        P = self.topo.num_ports
        wired = self.topo.neighbor.reshape(-1) >= 0
        util = np.where(self.capacity > 0, self.served / self.capacity, 0.0)
        util = np.where(wired, util, -1.0)
        order = np.argsort(-util)[:top]
        return [{
            "switch": int(l // P),
            "port": int(l % P),
            "neighbor": int(self.topo.neighbor.reshape(-1)[l]),
            "utilization": round(float(util[l]), 4),
            "capacity": round(float(self.capacity[l]), 4),
            "served": round(float(self.served[l]), 4),
        } for l in order if util[l] >= 0]


# ---------------------------------------------------------------------------
# Problem assembly per routing discipline


def _minimal_problem(topo, src, dst, rate) -> FlowProblem:
    link_ids, ptr = trace_routes(topo, src, dst)
    return FlowProblem(demand=np.asarray(rate, np.float64),
                       link_ids=link_ids, flow_ptr=ptr,
                       injection=_injection_mask(ptr),
                       src=np.asarray(src), dst=np.asarray(dst))


def _valiant_problem(topo, src, dst, rate,
                     params: FlowParams) -> FlowProblem:
    """Valiant load balancing as flow splitting: each demand spreads
    over intermediates ``mid ∉ {src, dst}``, both segments coupled into
    one flow per (pair, mid).  Exact enumeration within
    ``params.split_budget``; uniform mid *sampling* above it (the
    symmetric split a large fabric converges to anyway)."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    rate = np.asarray(rate, np.float64)
    n = topo.num_switches
    if n < 3:
        return _minimal_problem(topo, src, dst, rate)
    F = src.size
    if F * (n - 2) <= params.split_budget:
        m = n - 2
        raw = np.tile(np.arange(m), F)
    else:
        m = max(1, params.split_budget // max(F, 1))
        rng = np.random.default_rng(params.sample_seed)
        raw = rng.integers(0, n - 2, size=F * m)
    s = np.repeat(src, m)
    d = np.repeat(dst, m)
    lo = np.minimum(s, d)
    hi = np.maximum(s, d)
    mid = raw + (raw >= lo)
    mid += (mid >= hi)
    demand = np.repeat(rate / m, m)
    faults = (topo.meta or {}).get("faults")
    if faults is not None:
        # Degraded fabric: only mids alive and in the source's component
        # can relay.  Drop the rest and renormalize each pair's split
        # over its surviving mids; pairs with no surviving mid at all
        # route minimally (the same collapse the cycle engines apply).
        comp = faults["comp"]
        keep = comp[mid] == comp[s]
        if not keep.all():
            pair = np.repeat(np.arange(F), m)[keep]
            counts = np.bincount(pair, minlength=F)
            s, d, mid = s[keep], d[keep], mid[keep]
            demand = rate[pair] / np.maximum(counts[pair], 1)
            parts = []
            if s.size:
                link_ids, ptr = trace_routes_via(topo, s, mid, d)
                parts.append(FlowProblem(
                    demand=demand, link_ids=link_ids, flow_ptr=ptr,
                    injection=_injection_mask(ptr), src=s, dst=d))
            lost = counts == 0
            if lost.any():
                parts.append(_minimal_problem(topo, src[lost], dst[lost],
                                              rate[lost]))
            return _concat_problems(parts)
    link_ids, ptr = trace_routes_via(topo, s, mid, d)
    return FlowProblem(demand=demand,
                       link_ids=link_ids, flow_ptr=ptr,
                       injection=_injection_mask(ptr), src=s, dst=d)


def _adaptive_problem(topo, src, dst, rate,
                      params: FlowParams) -> FlowProblem:
    """UGAL in the fluid limit, matching ``AdaptivePolicy``'s backlog
    test structurally: route minimally, find the flows whose worst link
    would run ``detour_weight`` times hotter than the fabric average
    (and above nominal capacity), and send them Valiant.

    One engine behaviour needs modelling beyond per-flow detours: a
    switch's terminals share injection FIFOs, so when *any* of its
    flows backs up enough to detour, the colocated flows see the same
    backlog signal and detour with it.  Hence the escalation — every
    flow sourced at a switch hosting a detoured flow goes Valiant too.
    This reproduces the oracle's adaptive knees (hotspot 0.6 rather
    than the no-saturation a pure per-flow rule would predict)."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    rate = np.asarray(rate, np.float64)
    minimal = _minimal_problem(topo, src, dst, rate)
    cap = link_capacities(topo, minimal, params)
    L = cap.size
    entry_rate = np.repeat(minimal.demand, np.diff(minimal.flow_ptr))
    load_l = np.bincount(minimal.link_ids, weights=entry_rate, minlength=L)
    rho_l = load_l / cap
    entry_flow = np.repeat(np.arange(src.size), np.diff(minimal.flow_ptr))
    rho_f = np.zeros(src.size)
    np.maximum.at(rho_f, entry_flow, rho_l[minimal.link_ids])
    wired = topo.neighbor.reshape(-1) >= 0
    rho_bar = float(rho_l[wired].mean()) if wired.any() else 0.0
    detour = rho_f > max(params.detour_weight * rho_bar, 1.0)
    if not detour.any() or topo.num_switches < 3:
        return minimal
    go_valiant = np.isin(src, np.unique(src[detour]))
    parts = []
    if (~go_valiant).any():
        parts.append(_minimal_problem(topo, src[~go_valiant],
                                      dst[~go_valiant], rate[~go_valiant]))
    parts.append(_valiant_problem(topo, src[go_valiant], dst[go_valiant],
                                  rate[go_valiant], params))
    return _concat_problems(parts)


def solve_flows(topo: SimTopology, routing: str, src, dst, rate, *,
                params: FlowParams | None = None) -> FlowSolution:
    """Build and solve the flow problem for one demand matrix.

    On a degraded topology (:func:`repro.faults.degrade`), demand
    entries whose endpoints died or were disconnected are dropped here —
    the one choke point every demand source (analytic patterns,
    empirical traffic, direct calls) passes through — mirroring the
    packet masking the cycle engines apply.  Offered load stays measured
    against the pristine switch count, so throughput retention curves
    read directly as survivability.
    """
    params = params or FlowParams()
    if (topo.meta or {}).get("faults") is not None:
        from repro.faults import filter_pairs
        src, dst, rate = filter_pairs(topo, src, dst, rate)
    if routing == "minimal":
        problem = _minimal_problem(topo, src, dst, rate)
    elif routing == "valiant":
        problem = _valiant_problem(topo, src, dst, rate, params)
    elif routing == "adaptive":
        problem = _adaptive_problem(topo, src, dst, rate, params)
    else:
        raise ValueError(f"flow backend supports routing policies "
                         f"{ROUTINGS}, got {routing!r}")
    capacity = link_capacities(topo, problem, params)
    rates = maxmin_rates(problem.demand, problem.link_ids,
                         problem.flow_ptr, capacity,
                         max_iters=params.max_iters, solver=params.solver)
    return FlowSolution(topo=topo, routing=routing, problem=problem,
                        capacity=capacity, rates=rates, params=params)


# ---------------------------------------------------------------------------
# Declarative pattern → demand matrix


def pattern_demands(topo: SimTopology, pattern: str, load: float,
                    terminals: int, params: FlowParams,
                    traffic_params: dict | None = None):
    """(src, dst, rate) for a declarative ``TrafficSpec`` pattern —
    the *expected* demand matrix of the stochastic generator, so no
    generation-sized arrays exist at 10k-switch scale."""
    kw = dict(traffic_params or {})
    kw.pop("seed", None)        # fixed generator seed: irrelevant in the mean
    if pattern == "uniform":
        return uniform_demands(topo, load, terminals, params)
    if pattern == "permutation":
        return permutation_demands(topo, load, terminals, params,
                                   perm=kw.get("perm"))
    if pattern == "hotspot":
        return hotspot_demands(
            topo, load, terminals, params,
            hot_fraction=float(kw.get("hot_fraction", 0.8)),
            hot_dst=kw.get("hot_dst"),
            partner_shift=kw.get("partner_shift"))
    if pattern == "adversarial":
        return adversarial_demands(topo, load, terminals, params)
    raise ValueError(f"flow backend has no analytic demand model for "
                     f"traffic pattern {pattern!r}")


_TRAFFIC_NAMES = {"uniform": "uniform", "permutation": "permutation",
                  "hotspot": "hotspot",
                  "adversarial": "adversarial-same-group"}


# ---------------------------------------------------------------------------
# RunStats synthesis


def _weighted_percentile(values, weights, q) -> float:
    order = np.argsort(values)
    v, w = np.asarray(values)[order], np.asarray(weights)[order]
    cum = np.cumsum(w)
    if cum[-1] <= 0:
        return 0.0
    return float(v[np.searchsorted(cum, q / 100.0 * cum[-1])])


def _stats_from_solution(sol: FlowSolution, *, policy: str, traffic: str,
                         offered: float, cycles: int, warmup: int,
                         terminals: int) -> RunStats:
    """A RunStats whose throughput fields carry the flow prediction.

    Latency fields are **hop-count proxies** (``hops + 1``, the
    engines' contention-free minimum) and link utilization is offered-
    rate based — documented lower bounds, not queueing estimates."""
    topo = sol.topo
    n = topo.num_switches
    meas = max(cycles - warmup, 1)
    hops = np.diff(sol.problem.flow_ptr)
    w = sol.rates
    total = float(w.sum())
    lat = hops + 1
    if total > 0:
        lat_mean = float((lat * w).sum() / total)
        lat_p50 = _weighted_percentile(lat, w, 50)
        lat_p99 = _weighted_percentile(lat, w, 99)
        lat_max = int(lat[w > 0].max())
    else:
        lat_mean = lat_p50 = 0.0
        lat_p99 = 0.0
        lat_max = 0
    hist_counts = np.round(
        np.bincount(lat, weights=w) * meas).astype(np.int64) \
        if lat.size else np.zeros(1, dtype=np.int64)
    served = sol.served
    wired = topo.neighbor.reshape(-1) >= 0
    util = served[wired]
    mean = float(util.mean()) if util.size else 0.0
    cv = float(util.std() / mean) if mean > 0 else 0.0
    delivered_window = int(round(total * meas))
    return RunStats(
        topology=topo.name, policy=policy, traffic=traffic,
        offered=offered, cycles=cycles, warmup=warmup,
        num_switches=n, terminals=terminals,
        packets_generated=int(round(sol.offered_rate * cycles)),
        packets_delivered=int(round(total * cycles)),
        delivered_in_window=delivered_window,
        accepted=total / (n * max(terminals, 1)),
        latency_mean=lat_mean, latency_p50=lat_p50, latency_p99=lat_p99,
        latency_max=lat_max, latency_histogram=hist_counts,
        link_loads=np.round(served * cycles).astype(np.int64),
        link_util_max=float(util.max()) if util.size else 0.0,
        link_util_mean=mean, link_util_cv=cv,
        in_flight_at_end=0,
    )


# ---------------------------------------------------------------------------
# Collective replay estimation


def replay_estimate(topo: SimTopology, workload
                    ) -> tuple[list[int], np.ndarray]:
    """Per-phase completion bound: a phase of ``messages`` packets per
    pair whose worst directed link carries ``k`` overlapping pair
    routes serializes to ``messages * k`` cycles (the engine moves one
    packet per link per cycle and phases are barriered, so stochastic
    HOL losses don't apply — deterministic schedules drain their links
    back-to-back).  Returns ``(phase_cycles, lifetime link loads)``.

    This is exactly how the Dragonfly all-to-all's ~4.4x plateau
    arises: each global step funnels ``a`` pair routes over one global
    link (k = a), while CIN/HyperX LACIN schedules keep k = 1 and meet
    the contention-free bound.
    """
    L = topo.num_switches * topo.num_ports
    loads = np.zeros(L)
    phase_cycles: list[int] = []
    for ph in workload.phases:
        link_ids, _ptr = trace_routes(topo, np.asarray(ph.src),
                                      np.asarray(ph.dst))
        if link_ids.size:
            counts = np.bincount(link_ids, minlength=L)
            k = int(counts.max())
            loads += counts * int(ph.messages)
        else:
            k = 1
        phase_cycles.append(int(ph.messages) * max(k, 1))
    return phase_cycles, loads


def replay_stats(topo: SimTopology, policy: str, traffic, workload, *,
                 terminals: int) -> RunStats:
    """RunStats for a collective replay, flow-level fidelity."""
    phase_cycles, loads = replay_estimate(topo, workload)
    completion = int(sum(phase_cycles))
    horizon = max(completion, 1)
    n = topo.num_switches
    # Latency proxy: per-phase route lengths + 1, message-weighted.
    lat_vals: list[np.ndarray] = []
    lat_w: list[np.ndarray] = []
    packets = 0
    for ph in workload.phases:
        _ids, ptr = trace_routes(topo, np.asarray(ph.src),
                                 np.asarray(ph.dst))
        lat_vals.append(np.diff(ptr) + 1)
        lat_w.append(np.full(len(ph.src), float(ph.messages)))
        packets += len(ph.src) * int(ph.messages)
    lat = np.concatenate(lat_vals) if lat_vals else np.zeros(0, np.int64)
    w = np.concatenate(lat_w) if lat_w else np.zeros(0)
    total_w = float(w.sum())
    wired = topo.neighbor.reshape(-1) >= 0
    util = loads[wired] / horizon
    mean = float(util.mean()) if util.size else 0.0
    stats = RunStats(
        topology=topo.name, policy=policy, traffic=traffic.name,
        offered=float(traffic.offered), cycles=completion, warmup=0,
        num_switches=n, terminals=terminals,
        packets_generated=packets, packets_delivered=packets,
        delivered_in_window=packets,
        accepted=packets / (n * max(terminals, 1) * horizon),
        latency_mean=float((lat * w).sum() / total_w) if total_w else 0.0,
        latency_p50=_weighted_percentile(lat, w, 50) if total_w else 0.0,
        latency_p99=_weighted_percentile(lat, w, 99) if total_w else 0.0,
        latency_max=int(lat.max()) if lat.size else 0,
        latency_histogram=(np.bincount(lat, weights=w).astype(np.int64)
                           if lat.size else np.zeros(1, np.int64)),
        link_loads=loads.astype(np.int64),
        link_util_max=float(util.max()) if util.size else 0.0,
        link_util_mean=mean,
        link_util_cv=float(util.std() / mean) if mean > 0 else 0.0,
        in_flight_at_end=0,
    )
    stats.phase_cycles = tuple(int(c) for c in phase_cycles)
    stats.completion_cycles = completion
    stats.ideal_cycles = int(workload.ideal_cycles)
    return stats


# ---------------------------------------------------------------------------
# Serving streams


def serving_stats(topo: SimTopology, routing: str, traffic, *,
                  terminals: int, cycles: int, warmup: int = 0,
                  params: FlowParams | None = None) -> RunStats:
    """RunStats for a serving request stream at flow fidelity.

    Throughput comes from the max-min solution of the stream's empirical
    demand matrix (:func:`repro.workload.serving_demands`).  Per-request
    latency is the contention-free lower bound ``hops + P`` (a request's
    ``P`` packets serialize through one injection FIFO, so the last
    packet cannot deliver before ``hops + 1 + (P - 1)`` cycles after
    arrival), and requests on *saturated* pairs — allocated below their
    demanded rate — count as SLO misses outright.  Flow attainment is
    therefore an optimistic bound away from the knee and a hard zeroing
    at it: the same capacity cliff the cycle engines measure, at 10k+
    switch scale (cross-validated in tests/test_workload_serving.py).
    """
    from repro.workload.serving import serving_demands
    params = params or FlowParams()
    n = topo.num_switches
    src, dst, rate = serving_demands(traffic, n)
    sol = solve_flows(topo, routing, src, dst, rate, params=params)
    stats = _stats_from_solution(sol, policy=routing, traffic=traffic.name,
                                 offered=float(traffic.offered),
                                 cycles=cycles, warmup=warmup,
                                 terminals=terminals)
    slo = getattr(traffic, "slo", None)
    stats.slo_target = float(slo) if slo is not None else None
    if traffic.request is None or traffic.num_packets == 0:
        stats.request_count = 0
        return stats
    pair_in = src * n + dst                      # sorted (np.unique output)
    # Allocated rate per input pair: the solution's flows keep their
    # originating (src, dst) even when valiant splits them over mids.
    alloc = np.zeros(pair_in.size)
    pkey = (np.asarray(sol.problem.src, np.int64) * n
            + np.asarray(sol.problem.dst, np.int64))
    idx = np.searchsorted(pair_in, pkey)
    ok = idx < pair_in.size
    ok[ok] &= pair_in[idx[ok]] == pkey[ok]
    np.add.at(alloc, idx[ok], sol.rates[ok])
    sat_pair = alloc < rate * (1.0 - 1e-6)
    # Minimal-route hop counts per pair; pairs a degraded fabric dropped
    # stay untraced and count as misses (the engines mask their packets).
    keep = np.ones(pair_in.size, dtype=bool)
    if (topo.meta or {}).get("faults") is not None:
        from repro.faults import filter_pairs
        ksrc, kdst, _kr = filter_pairs(topo, src, dst, rate)
        keep = np.isin(pair_in, ksrc * n + kdst)
    hops = np.zeros(pair_in.size, dtype=np.int64)
    if keep.any():
        _ids, ptr = trace_routes(topo, src[keep], dst[keep])
        hops[keep] = np.diff(ptr)
    uniq, first, counts = np.unique(traffic.request, return_index=True,
                                    return_counts=True)
    r_pair = (traffic.src[first].astype(np.int64) * n
              + traffic.dst[first].astype(np.int64))
    pidx = np.searchsorted(pair_in, r_pair)
    lat = hops[pidx] + counts                    # hops + 1 + (P - 1)
    complete = keep[pidx] & ~sat_pair[pidx]
    stats.request_count = int(uniq.size)
    done = lat[complete]
    if done.size:
        p50, p95, p99 = np.percentile(done, [50, 95, 99])
        stats.request_latency_p50 = round(float(p50), 3)
        stats.request_latency_p95 = round(float(p95), 3)
        stats.request_latency_p99 = round(float(p99), 3)
    if slo is not None and uniq.size:
        met = int((done <= float(slo)).sum())
        stats.slo_attainment = round(met / uniq.size, 4)
    return stats


# ---------------------------------------------------------------------------
# Engine / Study seams


def _routing_from_policy(policy) -> tuple[str, FlowParams]:
    name = getattr(policy, "name", str(policy))
    if name not in ROUTINGS:
        raise ValueError(f"flow backend supports routing policies "
                         f"{ROUTINGS}, got {name!r}")
    params = FlowParams(detour_weight=float(getattr(policy, "weight", 2.0)))
    return name, params


def simulate_flow(topo: SimTopology, policy, traffic, *,
                  terminals: int | None = None, cycles: int | None = None,
                  warmup: int = 0, params: FlowParams | None = None,
                  **_engine_kw) -> RunStats:
    """The ``simulate(backend="flow")`` seam: same call shape as the
    cycle engines, flow-level fidelity out.  Queue-level knobs
    (``queue_capacity``, ``num_vcs``, ``eject_bw``, ``seed``, ...) are
    accepted and ignored — the fluid model has no queues."""
    from repro.sim.traffic import resolve_terminals
    routing, pparams = _routing_from_policy(policy)
    params = params or pparams
    T = resolve_terminals(traffic, terminals)
    if traffic.workload is not None:
        return replay_stats(topo, routing, traffic, traffic.workload,
                            terminals=T)
    if traffic.request is not None:
        horizon = (cycles if cycles is not None
                   else max(int(traffic.horizon), 1))
        return serving_stats(topo, routing, traffic, terminals=T,
                             cycles=horizon, warmup=warmup, params=params)
    src, dst, rate = demands_from_traffic(traffic, topo.num_switches)
    # Empirical per-horizon rates are per-fabric totals already; the
    # generator drew them at `offered * terminals` per switch.
    sol = solve_flows(topo, routing, src, dst, rate, params=params)
    horizon = cycles if cycles is not None else max(int(traffic.horizon), 1)
    return _stats_from_solution(sol, policy=routing, traffic=traffic.name,
                                offered=float(traffic.offered),
                                cycles=horizon, warmup=warmup, terminals=T)


def study_point_stats(exp, topo: SimTopology, tf, load: float, seed: int, *,
                      params: FlowParams | None = None) -> RunStats:
    """One Study grid point at flow fidelity.

    Declarative open-loop patterns use their *analytic* demand matrix
    (nothing generation-sized is materialized, which is what makes the
    10k-switch grid points cheap); ``workload`` traffic goes through
    the replay estimator; inline traffic falls back to the empirical
    matrix of the generated packets.
    """
    routing = exp.routing.label
    if routing not in ROUTINGS:
        raise ValueError(f"flow backend supports routing policies "
                         f"{ROUTINGS}, got {routing!r}")
    rparams = dict(exp.routing.params or {})
    params = params or FlowParams(
        detour_weight=float(rparams.get("weight", 2.0)))
    terminals = exp.terminals if exp.terminals is not None else 1
    sweep = exp.sweep
    pattern = exp.traffic.pattern

    if pattern == "workload":
        traffic = tf(load, seed)
        return replay_stats(topo, routing, traffic, traffic.workload,
                            terminals=terminals)
    if pattern == "serving":
        traffic = tf(load, seed)
        cycles = (sweep.cycles if sweep.cycles is not None
                  else max(int(traffic.horizon), 1))
        warmup = sweep.warmup if sweep.warmup is not None else 0
        return serving_stats(topo, routing, traffic, terminals=terminals,
                             cycles=cycles, warmup=warmup, params=params)
    if pattern in _TRAFFIC_NAMES:
        src, dst, rate = pattern_demands(topo, pattern, load, terminals,
                                         params, dict(exp.traffic.params))
        sol = solve_flows(topo, routing, src, dst, rate, params=params)
        cycles = sweep.cycles if sweep.cycles is not None else 1
        warmup = (sweep.warmup if sweep.warmup is not None
                  else cycles // 4)
        return _stats_from_solution(
            sol, policy=routing, traffic=_TRAFFIC_NAMES[pattern],
            offered=load, cycles=cycles, warmup=warmup,
            terminals=terminals)
    # Inline traffic: generate once and read off the empirical matrix.
    traffic = tf(load, seed)
    cycles = (sweep.cycles if sweep.cycles is not None
              else max(int(traffic.horizon), 1))
    warmup = (sweep.warmup if sweep.warmup is not None
              else 0 if traffic.workload is not None else cycles // 4)
    return simulate_flow(topo, type("P", (), {"name": routing})(), traffic,
                         terminals=terminals, cycles=cycles, warmup=warmup,
                         params=params)


# ---------------------------------------------------------------------------
# Saturation search (benchmarks / examples)


def saturation_load(topo: SimTopology, *, routing: str = "minimal",
                    pattern: str = "uniform", terminals: int = 1,
                    params: FlowParams | None = None,
                    traffic_params: dict | None = None,
                    lo: float = 0.01, hi: float = 2.0, tol: float = 0.005,
                    threshold: float = 0.95) -> float | None:
    """The flow model's saturation knee by bisection: the smallest
    offered load where accepted throughput drops below ``threshold *
    offered``.  Returns ``None`` when the fabric never saturates below
    ``hi`` (per-terminal loads above 1.0 are not injectable anyway).
    """
    params = params or FlowParams()

    def saturated(load: float) -> bool:
        src, dst, rate = pattern_demands(topo, pattern, load, terminals,
                                         params, traffic_params)
        sol = solve_flows(topo, routing, src, dst, rate, params=params)
        accepted = sol.delivered_rate / (topo.num_switches
                                         * max(terminals, 1))
        return accepted < threshold * load

    if not saturated(hi):
        return None
    if saturated(lo):
        return lo
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if saturated(mid):
            hi = mid
        else:
            lo = mid
    return round(hi, 4)
