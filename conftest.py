"""Repo-level pytest configuration.

* Puts ``src/`` on ``sys.path`` so ``import repro`` works without an
  editable install (mirrors the tier-1 ``PYTHONPATH=src`` invocation).
* Gates the optional ``hypothesis`` dependency: when it is not installed
  (hermetic CI images), a deterministic fallback sampler is registered so
  the property tests still run.
* Points the persistent compile cache (``repro.obs.telemetry``) at a
  fresh per-session temporary directory so tests are hermetic: runs
  never hit executables a previous session (or the user's real
  ``~/.cache/lacin-repro``) left behind, and the cold-compile
  assertions stay meaningful.  Tests that need a specific directory (or
  a disabled cache) still override ``LACIN_CACHE_DIR`` themselves.
"""
import os
import sys
import tempfile

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

os.environ["LACIN_CACHE_DIR"] = tempfile.mkdtemp(prefix="lacin-test-cache-")

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro._compat import hypothesis_fallback
    hypothesis_fallback.install()
