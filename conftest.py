"""Repo-level pytest configuration.

* Puts ``src/`` on ``sys.path`` so ``import repro`` works without an
  editable install (mirrors the tier-1 ``PYTHONPATH=src`` invocation).
* Gates the optional ``hypothesis`` dependency: when it is not installed
  (hermetic CI images), a deterministic fallback sampler is registered so
  the property tests still run.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro._compat import hypothesis_fallback
    hypothesis_fallback.install()
