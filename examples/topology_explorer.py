"""Topology explorer: inspect any CIN instance / HyperX / Dragonfly.

    PYTHONPATH=src python examples/topology_explorer.py cin --instance circle --n 12
    PYTHONPATH=src python examples/topology_explorer.py hyperx --dims 8 8 8 --terminals 8
    PYTHONPATH=src python examples/topology_explorer.py dragonfly --groups 16 --group-size 8

A :mod:`repro.studies` spec file (or bundled spec name) names its
fabrics declaratively, so the explorer can open those too — one report
per distinct fabric in the study:

    PYTHONPATH=src python examples/topology_explorer.py spec cin16_saturation
    PYTHONPATH=src python examples/topology_explorer.py spec my_experiment.json
"""
import argparse

import numpy as np

from repro.core import (column_report, factorization, instance_crossings,
                        lacin_total_wire_length, port_matrix,
                        swap_to_lacin_ratio, verify_instance)
from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig, HyperXDeployment


def show_cin(args):
    inst, n = args.instance, args.n
    P = port_matrix(inst, n)
    print(f"P matrix ({inst}, N={n}):")
    print(P if n <= 16 else f"  [{n}x{n-1}] (too large to print)")
    print("verify:", verify_instance(inst, n))
    print(f"LACIN total wire length: {lacin_total_wire_length(n)}")
    if inst == "swap":
        print(f"oblique/straight ratio: {swap_to_lacin_ratio(n):.4f}")
    else:
        f = factorization(inst, n)
        print(f"1-factors: {len(f)} x {len(f[0])} links")
        print(f"naive crossings/column: {instance_crossings(inst, n)}")
    for row in column_report(inst, n)[:4]:
        print("  column:", row)


def show_hyperx(args):
    cfg = HyperXConfig(dims=tuple(args.dims), terminals=args.terminals,
                       instance=args.instance)
    dep = HyperXDeployment(cfg)
    for k, v in dep.report().items():
        print(f"  {k} = {v}")
    a, b = 0, cfg.num_switches - 1
    print("sample DOR route corner->corner:",
          cfg.dor_route(cfg.switch_coord(a), cfg.switch_coord(b)))


def show_dragonfly(args):
    d = DragonflyConfig(group_size=args.group_size,
                        terminals_per_switch=args.terminals,
                        global_ports_per_switch=args.global_ports,
                        num_groups=args.groups)
    print(f"  switches={d.switches} endpoints={d.endpoints} radix={d.radix}")
    print(f"  local links/group={d.local_links_per_group} "
          f"global={d.global_links} total={d.total_links}")
    print("sample l-g-l route:",
          d.route_packet((0, 0, 0), (args.groups - 1, args.group_size - 1, 1)))


def show_spec(args):
    """Every distinct fabric a study spec file names, verified."""
    from repro import studies
    src = studies.resolve_spec_source(args.spec)
    specs = studies.load_specs(src)
    seen = {}
    for exp in specs:
        key = exp.fabric.to_json()
        seen.setdefault(key, (exp.fabric, []))[1].append(exp)
    print(f"{src}: {len(specs)} experiments over {len(seen)} fabrics")
    for fabric_spec, exps in seen.values():
        fab = fabric_spec.resolve()
        print(f"\n== {fab.name} ({fabric_spec.kind}) ==")
        for k, v in fab.deployment().items():
            print(f"  {k} = {v}")
        report = fab.verify()
        print(f"  verify ok = {report['ok']}")
        for exp in exps:
            print(f"  - {exp.describe()}")


def main():
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="cmd", required=True)
    c = sub.add_parser("cin")
    c.add_argument("--instance", default="circle",
                   choices=["swap", "circle", "xor"])
    c.add_argument("--n", type=int, default=8)
    h = sub.add_parser("hyperx")
    h.add_argument("--dims", type=int, nargs="+", default=[4, 4, 4])
    h.add_argument("--terminals", type=int, default=4)
    h.add_argument("--instance", default="xor")
    d = sub.add_parser("dragonfly")
    d.add_argument("--groups", type=int, default=16)
    d.add_argument("--group-size", type=int, default=8)
    d.add_argument("--terminals", type=int, default=4)
    d.add_argument("--global-ports", type=int, default=2)
    s = sub.add_parser("spec", help="inspect the fabrics of a study spec")
    s.add_argument("spec", help="spec file path or bundled spec name")
    args = ap.parse_args()
    {"cin": show_cin, "hyperx": show_hyperx,
     "dragonfly": show_dragonfly, "spec": show_spec}[args.cmd](args)


if __name__ == "__main__":
    main()
