import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Multi-device demo: LACIN-scheduled collectives + explicit-DP training.

    PYTHONPATH=src python examples/multidev_collectives.py

Runs on 8 host devices: (1) compares the XOR/Circle/cyclic step schedules
against lax.psum on an all-reduce; (2) trains a tiny LM where the gradient
all-reduce is the paper's 1-factor schedule (optionally int8-compressed).
"""
import time

import numpy as np

import jax
import jax.numpy as jnp
from repro._compat.jaxapi import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import make_schedule
from repro.fabric import LacinCollectives


def bench_allreduce(mesh, n):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 1 << 20))
    rows = []
    for inst in ("xor", "circle", "cyclic"):
        coll = LacinCollectives(mesh=mesh, instance=inst)
        f = jax.jit(shard_map(
            lambda xl, c=coll: c.all_reduce(xl[0], "x")[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))
        jax.block_until_ready(f(x))
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(f(x))
        rows.append((inst, (time.perf_counter() - t0) / 5 * 1e3))
    f = jax.jit(shard_map(lambda xl: jax.lax.psum(xl[0], "x")[None],
                          mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    jax.block_until_ready(f(x))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(f(x))
    rows.append(("xla_psum", (time.perf_counter() - t0) / 5 * 1e3))
    return rows


def main():
    devs = jax.devices()
    n = len(devs)
    mesh = Mesh(np.array(devs), ("x",))
    print(f"devices: {n}")

    s = make_schedule("auto", n)
    print(f"schedule: {s.instance}, {s.num_steps} steps, "
          f"matching/step={s.is_matching_per_step()}")

    print("\nall-reduce of 4 MiB x 8 shards:")
    for name, ms in bench_allreduce(mesh, n):
        print(f"  {name:9s} {ms:7.2f} ms")

    # hierarchical: two-level Dragonfly-style all-reduce on a (2, 4) mesh
    if n == 8:
        import jax.numpy as jnp
        mesh2 = Mesh(np.array(devs).reshape(2, 4), ("g", "l"))
        coll = LacinCollectives(mesh=mesh2)
        x = jnp.ones((n, 1 << 10))
        y = shard_map(lambda xl: coll.all_reduce_two_level(xl[0], "l", "g")[None],
                      mesh=mesh2, in_specs=P(("g", "l")),
                      out_specs=P(("g", "l")))(x)
        print(f"\ntwo-level all-reduce on (g=2, l=4): sum={float(y[0,0]):.0f} "
              f"(expect {n})")

    print("\nexplicit-DP training with LACIN gradient all-reduce:")
    from repro.models import get_config
    from repro.optim import OptConfig
    from repro.runtime.manual_dp import make_manual_dp_train_step
    from repro.runtime.trainer import init_train_state

    cfg = get_config("lacin-demo").reduced()
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (n * 2, 32)), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    for compress in (False, True):
        step = make_manual_dp_train_step(
            cfg, mesh, OptConfig(lr=1e-3), axis_name="x", compress=compress)
        # fresh state per run: the step donates its input buffers
        st = init_train_state(jax.random.PRNGKey(0), cfg)
        losses = []
        for _ in range(5):
            st, m = step(st, batch)
            losses.append(float(m["loss"]))
        tag = "int8-compressed" if compress else "fp32"
        print(f"  {tag:16s} losses: " + " ".join(f"{l:.3f}" for l in losses))
    print("done.")


if __name__ == "__main__":
    main()
