import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Multi-device demo: LACIN-scheduled collectives + explicit-DP training.

    PYTHONPATH=src python examples/multidev_collectives.py

Runs on 8 host devices: (1) compares the XOR/Circle/cyclic step schedules
against lax.psum on an all-reduce; (2) trains a tiny LM where the gradient
all-reduce is the paper's 1-factor schedule (optionally int8-compressed).
"""
import time

import numpy as np

import jax
import jax.numpy as jnp
from repro._compat.jaxapi import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import all_reduce_lacin, make_schedule


def bench_allreduce(mesh, n):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 1 << 20))
    rows = []
    for inst in ("xor", "circle", "cyclic"):
        f = jax.jit(shard_map(
            lambda xl, inst=inst: all_reduce_lacin(
                xl[0], "x", axis_size=n, instance=inst)[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))
        jax.block_until_ready(f(x))
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(f(x))
        rows.append((inst, (time.perf_counter() - t0) / 5 * 1e3))
    f = jax.jit(shard_map(lambda xl: jax.lax.psum(xl[0], "x")[None],
                          mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    jax.block_until_ready(f(x))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(f(x))
    rows.append(("xla_psum", (time.perf_counter() - t0) / 5 * 1e3))
    return rows


def main():
    devs = jax.devices()
    n = len(devs)
    mesh = Mesh(np.array(devs), ("x",))
    print(f"devices: {n}")

    s = make_schedule("auto", n)
    print(f"schedule: {s.instance}, {s.num_steps} steps, "
          f"matching/step={s.is_matching_per_step()}")

    print("\nall-reduce of 4 MiB x 8 shards:")
    for name, ms in bench_allreduce(mesh, n):
        print(f"  {name:9s} {ms:7.2f} ms")

    print("\nexplicit-DP training with LACIN gradient all-reduce:")
    from repro.models import get_config
    from repro.optim import OptConfig
    from repro.runtime.manual_dp import make_manual_dp_train_step
    from repro.runtime.trainer import init_train_state

    cfg = get_config("lacin-demo").reduced()
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (n * 2, 32)), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    for compress in (False, True):
        step = make_manual_dp_train_step(
            cfg, mesh, OptConfig(lr=1e-3), axis_name="x", compress=compress)
        # fresh state per run: the step donates its input buffers
        st = init_train_state(jax.random.PRNGKey(0), cfg)
        losses = []
        for _ in range(5):
            st, m = step(st, batch)
            losses.append(float(m["loss"]))
        tag = "int8-compressed" if compress else "fp32"
        print(f"  {tag:16s} losses: " + " ".join(f"{l:.3f}" for l in losses))
    print("done.")


if __name__ == "__main__":
    main()
