"""Saturation sweep demo: offered load vs accepted throughput + latency.

Sweeps the packet-level simulator over a topology and prints one table
per traffic pattern, comparing routing policies — the experiment shape
behind the paper's §3 minimal-vs-non-minimal discussion.

By default the sweep runs on the compiled JAX engine
(:mod:`repro.sim.xengine`): every (load, seed) point batches into one
jit-compiled program.  ``--backend numpy`` uses the interpreted oracle
engine instead (one Python iteration per simulated cycle).

Usage (from the repo root):

    PYTHONPATH=src python examples/saturation_sweep.py
    PYTHONPATH=src python examples/saturation_sweep.py --topo hyperx --dims 8,8
    PYTHONPATH=src python examples/saturation_sweep.py --topo dragonfly \
        --traffic adversarial --policies minimal,valiant
    PYTHONPATH=src python examples/saturation_sweep.py --seeds 0,1,2 --json sweep.json
    PYTHONPATH=src python examples/saturation_sweep.py --backend numpy
"""
from __future__ import annotations

import argparse
import sys
import time

from repro import sim
from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig


def build_topology(args):
    if args.topo == "cin":
        return sim.cin_topology(args.instance, args.n)
    if args.topo == "hyperx":
        dims = tuple(int(d) for d in args.dims.split(","))
        return sim.hyperx_topology(HyperXConfig(dims=dims,
                                                terminals=args.terminals,
                                                instance=args.instance))
    if args.topo == "dragonfly":
        return sim.dragonfly_topology(DragonflyConfig(
            group_size=4, terminals_per_switch=args.terminals,
            global_ports_per_switch=2, num_groups=8))
    raise SystemExit(f"unknown topology {args.topo!r}")


def traffic_factory(args, topo, pattern):
    n = topo.num_switches
    if pattern == "uniform":
        return lambda load, seed: sim.uniform(
            n, offered=load, cycles=args.cycles, terminals=args.terminals,
            seed=seed)
    if pattern == "hotspot":
        return lambda load, seed: sim.hotspot(
            n, offered=load, cycles=args.cycles, terminals=args.terminals,
            hot_fraction=0.9, seed=seed)
    if pattern == "permutation":
        return lambda load, seed: sim.permutation(
            n, offered=load, cycles=args.cycles, terminals=args.terminals,
            seed=seed)
    if pattern == "adversarial":
        cfg = topo.meta.get("config")
        if not isinstance(cfg, DragonflyConfig):
            raise SystemExit("adversarial traffic needs --topo dragonfly")
        return lambda load, seed: sim.adversarial_same_group(
            cfg, offered=load, cycles=args.cycles, terminals=args.terminals,
            seed=seed)
    raise SystemExit(f"unknown traffic pattern {pattern!r}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--topo", default="cin",
                    choices=["cin", "hyperx", "dragonfly"])
    ap.add_argument("--instance", default="xor",
                    choices=["xor", "circle", "swap"])
    ap.add_argument("--n", type=int, default=16, help="CIN switch count")
    ap.add_argument("--dims", default="8,8", help="HyperX dims, e.g. 8,8")
    ap.add_argument("--terminals", type=int, default=8,
                    help="injectors per switch")
    ap.add_argument("--policies", default="minimal,valiant,adaptive")
    ap.add_argument("--traffic", default="uniform,hotspot",
                    help="comma list: uniform,hotspot,permutation,adversarial")
    ap.add_argument("--loads", default="0.1,0.3,0.5,0.7,0.9")
    ap.add_argument("--cycles", type=int, default=1000)
    ap.add_argument("--seeds", default="0",
                    help="comma list; the jax backend batches all seeds "
                         "with all loads into one compiled program")
    ap.add_argument("--backend", default="jax", choices=["jax", "numpy"],
                    help="jax = compiled batched engine, numpy = oracle")
    ap.add_argument("--json", default=None, help="write records to this path")
    args = ap.parse_args(argv)

    topo = build_topology(args)
    loads = [float(x) for x in args.loads.split(",")]
    seeds = tuple(int(s) for s in args.seeds.split(","))
    policies = args.policies.split(",")
    print(f"topology: {topo.name}  switches={topo.num_switches} "
          f"ports={topo.num_ports} links={topo.num_links} "
          f"terminals={args.terminals} backend={args.backend}")

    everything = []
    for pattern in args.traffic.split(","):
        tf = traffic_factory(args, topo, pattern)
        t0 = time.time()
        stats = []
        for pol in policies:
            if args.backend == "jax":
                grid = sim.sim_sweep(
                    topo, pol, tf, loads, seeds=seeds,
                    terminals=args.terminals, cycles=args.cycles,
                    warmup=args.cycles // 4)
                stats += [s for per_load in grid for s in per_load]
            else:
                for seed in seeds:
                    stats += sim.saturation_sweep(
                        topo, lambda p=pol: sim.make_policy(p),
                        lambda load, s=seed: tf(load, s), loads,
                        terminals=args.terminals, cycles=args.cycles,
                        warmup=args.cycles // 4, seed=seed)
        everything += stats
        print(f"\n== {pattern} traffic "
              f"({len(policies) * len(loads) * len(seeds)} runs, "
              f"{time.time() - t0:.1f}s) ==")
        print(sim.format_table(stats))
        for pol in policies:
            knee = sim.saturation_point(
                [s for s in stats if s.policy == pol])
            print(f"  saturation point ({pol}): "
                  f"{knee if knee is not None else '> max load'}")

    if args.json:
        sim.save_json(everything, args.json)
        print(f"\nwrote {len(everything)} records to {args.json}")


if __name__ == "__main__":
    sys.exit(main())
