"""Saturation sweep demo: offered load vs accepted throughput + latency.

Builds declarative :mod:`repro.studies` experiment specs — one per
(traffic pattern, routing policy) — and runs them as a Study, printing
one table per traffic pattern: the experiment shape behind the paper's
§3 minimal-vs-non-minimal discussion.

The Study auto-selects the backend (the compiled JAX engine batches
every (load, seed) point of an experiment into one jit-compiled
program; ``--backend numpy`` forces the interpreted oracle), and with
``--store`` it streams JSONL result records that a re-run resumes from.

Usage (from the repo root):

    PYTHONPATH=src python examples/saturation_sweep.py
    PYTHONPATH=src python examples/saturation_sweep.py --topo hyperx --dims 8,8
    PYTHONPATH=src python examples/saturation_sweep.py --topo dragonfly \
        --traffic adversarial --policies minimal,valiant
    PYTHONPATH=src python examples/saturation_sweep.py --seeds 0,1,2 \
        --store sweep.jsonl
    PYTHONPATH=src python examples/saturation_sweep.py --backend numpy

    # the same sweep as a reusable spec file:
    PYTHONPATH=src python examples/saturation_sweep.py --emit-spec my.json
    PYTHONPATH=src python examples/saturation_sweep.py --spec my.json
    PYTHONPATH=src python examples/saturation_sweep.py --spec cin16_saturation
"""
from __future__ import annotations

import argparse
import sys
import time

from repro import studies
from repro.sim.report import format_table


def build_fabric_spec(args) -> studies.FabricSpec:
    if args.topo == "cin":
        return studies.FabricSpec("cin", {"instance": args.instance,
                                          "n": args.n})
    if args.topo == "hyperx":
        dims = [int(d) for d in args.dims.split(",")]
        return studies.FabricSpec("hyperx", {"dims": dims,
                                             "terminals": args.terminals,
                                             "instance": args.instance})
    if args.topo == "dragonfly":
        return studies.FabricSpec("dragonfly", {
            "group_size": 4, "terminals_per_switch": args.terminals,
            "global_ports_per_switch": 2, "num_groups": 8})
    raise SystemExit(f"unknown topology {args.topo!r}")


def build_specs(args) -> list[studies.ExperimentSpec]:
    fabric = build_fabric_spec(args)
    loads = tuple(float(x) for x in args.loads.split(","))
    seeds = tuple(int(s) for s in args.seeds.split(","))
    sweep = studies.SweepSpec(loads=loads, seeds=seeds, cycles=args.cycles,
                              warmup=args.cycles // 4)
    traffic_params = {"hotspot": {"hot_fraction": 0.9}}
    specs = []
    for pattern in args.traffic.split(","):
        traffic = studies.TrafficSpec(pattern,
                                      traffic_params.get(pattern, {}))
        for pol in args.policies.split(","):
            specs.append(studies.ExperimentSpec(
                fabric=fabric, traffic=traffic,
                routing=studies.RoutingSpec(pol), sweep=sweep,
                terminals=args.terminals))
    return specs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--spec", default=None,
                    help="run this spec file (or bundled spec name) instead "
                         "of building one from the flags below")
    ap.add_argument("--emit-spec", default=None, metavar="PATH",
                    help="write the spec the flags describe to PATH and exit")
    ap.add_argument("--topo", default="cin",
                    choices=["cin", "hyperx", "dragonfly"])
    ap.add_argument("--instance", default="xor",
                    choices=["xor", "circle", "swap"])
    ap.add_argument("--n", type=int, default=16, help="CIN switch count")
    ap.add_argument("--dims", default="8,8", help="HyperX dims, e.g. 8,8")
    ap.add_argument("--terminals", type=int, default=8,
                    help="injectors per switch")
    ap.add_argument("--policies", default="minimal,valiant,adaptive")
    ap.add_argument("--traffic", default="uniform,hotspot",
                    help="comma list: uniform,hotspot,permutation,adversarial")
    ap.add_argument("--loads", default="0.1,0.3,0.5,0.7,0.9")
    ap.add_argument("--cycles", type=int, default=1000)
    ap.add_argument("--seeds", default="0",
                    help="comma list; the jax backend batches all seeds "
                         "with all loads into one compiled program")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "jax", "numpy"],
                    help="auto picks the compiled batched engine when "
                         "JAX is available")
    ap.add_argument("--store", default=None,
                    help="stream result records to this JSONL store "
                         "(re-runs resume from it)")
    args = ap.parse_args(argv)

    if args.spec is not None:
        specs = studies.load_specs(studies.resolve_spec_source(args.spec))
    else:
        specs = build_specs(args)

    if args.emit_spec:
        studies.dump_specs(specs, args.emit_spec, study="saturation_sweep",
                           description="generated by examples/"
                                       "saturation_sweep.py")
        print(f"wrote {len(specs)} experiments to {args.emit_spec}")
        return 0

    study = studies.Study(specs, store=args.store, backend=args.backend)
    first = specs[0].fabric.resolve_topology()
    print(f"topology: {first.name}  switches={first.num_switches} "
          f"ports={first.num_ports} links={first.num_links}")

    t0 = time.time()
    out = study.run()
    print(f"ran {out.executed} grid points ({out.restored} restored) on "
          f"backend={out.backend} in {time.time() - t0:.1f}s")

    by_pattern: dict[str, list[studies.Result]] = {}
    for r in out.results:
        by_pattern.setdefault(r.traffic, []).append(r)
    knees = out.saturation_points()
    for pattern, results in by_pattern.items():
        print(f"\n== {pattern} traffic ({len(results)} runs) ==")
        print(format_table(results))
    for name, knee in knees.items():
        print(f"  saturation point ({name}): "
              f"{knee if knee is not None else '> max load'}")
    if args.store:
        print(f"\nresult store: {args.store}")


if __name__ == "__main__":
    sys.exit(main())
