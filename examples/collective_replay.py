"""Collective replay demo: predicted vs measured schedule completion.

The paper's §2 claim is that isoport LACIN wiring makes every 1-factor
schedule step contention-free, so a stepwise all-to-all completes in
exactly ``num_steps x message_size`` link cycles.  This demo *measures*
that: it converts each fabric's own collective schedule into a
phase-barriered workload (:mod:`repro.sim.workloads`) and replays it
through the packet simulator — queueing, credits, and VCs in the loop —
printing measured completion against the contention-free bound and the
per-phase breakdown.

Expected output: the CIN and HyperX all-to-all replays meet the bound
exactly (ratio 1.00) under minimal routing; the Dragonfly replay's
global phases serialize ``group_size`` flows over each single global
link (ratio ~a/h'ish), which is precisely the locality the two-level
all-reduce sequence (``--collective all_reduce``) is shaped to avoid.

Usage (from the repo root):

    PYTHONPATH=src python examples/collective_replay.py
    PYTHONPATH=src python examples/collective_replay.py --fabric hyperx \
        --message-size 4 --backend jax
    PYTHONPATH=src python examples/collective_replay.py --fabric dragonfly \
        --collective all_reduce --policies minimal,valiant
    PYTHONPATH=src python examples/collective_replay.py --phases

The same comparison, declaratively (persisted + resumable):

    PYTHONPATH=src python -m repro.studies run collective_replay
"""
from __future__ import annotations

import argparse

from repro.core.dragonfly import DragonflyConfig
from repro.core.hyperx import HyperXConfig
from repro.fabric import make_fabric
from repro.sim import workloads


def build_fabrics(which: str):
    fabs = {
        "cin": make_fabric("xor", 16),
        "hyperx": make_fabric(HyperXConfig(dims=(8, 8), terminals=4)),
        "dragonfly": make_fabric(DragonflyConfig(
            group_size=4, terminals_per_switch=2,
            global_ports_per_switch=2, num_groups=8)),
    }
    return list(fabs.values()) if which == "all" else [fabs[which]]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fabric", default="all",
                    choices=["all", "cin", "hyperx", "dragonfly"])
    ap.add_argument("--collective", default="all_to_all",
                    choices=["all_to_all", "all_reduce"])
    ap.add_argument("--message-size", type=int, default=2)
    ap.add_argument("--policies", default="minimal,adaptive",
                    help="comma-separated routing policies to compare")
    ap.add_argument("--backend", default="numpy", choices=["numpy", "jax"])
    ap.add_argument("--phases", action="store_true",
                    help="print the per-phase cycle breakdown")
    args = ap.parse_args(argv)

    policies = args.policies.split(",")
    hdr = (f"{'fabric':<22} {'policy':<10} {'phases':>6} {'ideal':>6} "
           f"{'measured':>9} {'ratio':>6}")
    print(f"collective={args.collective} message_size={args.message_size} "
          f"backend={args.backend}")
    print(hdr)
    print("-" * len(hdr))
    for fab in build_fabrics(args.fabric):
        w = workloads.collective_workload(fab, args.collective,
                                          message_size=args.message_size)
        for policy in policies:
            stats = workloads.replay(fab.sim_topology(), policy, w,
                                     backend=args.backend)
            ratio = stats.completion_cycles / max(stats.ideal_cycles, 1)
            print(f"{fab.name:<22} {policy:<10} {w.num_phases:>6} "
                  f"{stats.ideal_cycles:>6} {stats.completion_cycles:>9} "
                  f"{ratio:>6.2f}")
            if args.phases:
                print(f"    phase cycles: {list(stats.phase_cycles)}")
    print()
    print("ratio 1.00 = the schedule ran contention-free under queueing "
          "(the paper's isoport claim); above 1.00 = measured "
          "serialization the schedule algebra cannot see.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
