"""Serving example: batched requests through the continuous-batching
engine (prefill + lockstep decode over KV caches).

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

import jax

from repro.models import get_config
from repro.models.transformer import init_params
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = get_config("lacin-demo").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, slots=4, max_seq=64)

    rng = np.random.default_rng(0)
    for rid in range(4):
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
            max_new_tokens=12,
            temperature=0.8 if rid % 2 else 0.0))

    done = engine.run()
    for r in sorted(done, key=lambda r: r.rid):
        mode = "sampled" if r.temperature else "greedy"
        print(f"request {r.rid} ({mode}): prompt={r.prompt.tolist()} "
              f"-> {r.out_tokens}")
    print(f"served {len(done)} requests in lockstep decode.")


if __name__ == "__main__":
    main()
