"""Flow-backend scale demo: a 10k-switch sweep the cycle engines
cannot reach, plus bottleneck-link-set reporting.

Builds an extreme-scale Dragonfly (10,016 switches / ~160k endpoints by
default — the deployment regime of the paper's §5 comparison), sweeps
offered load at flow-level fidelity through the regular
:mod:`repro.studies` Study surface (each grid point is one max-min
fair-share solve, seconds rather than hours), and then asks the model
the question a cycle engine cannot answer at this scale: *which links
bind first*, via :meth:`repro.flow.FlowSolution.bottleneck_links`.

Usage (from the repo root):

    PYTHONPATH=src python examples/flow_scale.py
    PYTHONPATH=src python examples/flow_scale.py --fabric hyperx
    PYTHONPATH=src python examples/flow_scale.py --routing valiant \
        --loads 0.1,0.2,0.4
    PYTHONPATH=src python examples/flow_scale.py --store flow10k.jsonl
"""
from __future__ import annotations

import argparse
import time

from repro import studies
from repro.flow import FlowParams, pattern_demands, solve_flows

FABRICS = {
    # a=32 switches/group, h=10 global ports, 313 groups -> 10016 switches
    "dragonfly": studies.FabricSpec("dragonfly", {
        "group_size": 32, "terminals_per_switch": 16,
        "global_ports_per_switch": 10, "num_groups": 313}),
    # 100x100 circle HyperX -> 10000 switches
    "hyperx": studies.FabricSpec("hyperx", {
        "dims": [100, 100], "terminals": 16, "instance": "circle"}),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fabric", default="dragonfly", choices=sorted(FABRICS))
    ap.add_argument("--routing", default="minimal",
                    choices=["minimal", "valiant", "adaptive"])
    ap.add_argument("--loads", default="0.1,0.2,0.4,0.6")
    ap.add_argument("--terminals", type=int, default=16)
    ap.add_argument("--top", type=int, default=8,
                    help="bottleneck links to report")
    ap.add_argument("--store", default=None,
                    help="JSONL store (resumable, fidelity='flow' records)")
    args = ap.parse_args(argv)

    fabric = FABRICS[args.fabric]
    loads = tuple(float(x) for x in args.loads.split(","))
    spec = studies.ExperimentSpec(
        fabric=fabric,
        traffic=studies.TrafficSpec("uniform"),
        routing=studies.RoutingSpec(args.routing),
        sweep=studies.SweepSpec(loads=loads, seeds=(0,), cycles=600,
                                warmup=150),
        terminals=args.terminals)
    n = fabric.num_switches
    print(f"fabric: {fabric.label} ({n} switches, "
          f"{n * args.terminals} endpoints)")
    print(f"sweep: loads={list(loads)} routing={args.routing} "
          f"backend=flow (auto would escalate too: {n} >= "
          f"{studies.FLOW_AUTO_SWITCHES})")

    t0 = time.time()
    # backend="auto" would pick "flow" as well -- the fabric is far past
    # FLOW_AUTO_SWITCHES -- but be explicit in a demo about the model.
    out = studies.Study(spec, store=args.store, backend="flow").run()
    dt = time.time() - t0
    print(f"ran {out.executed} grid points "
          f"({out.restored} restored) in {dt:.1f}s")
    for r in out.results:
        sat = "saturated" if r.saturated else "ok"
        print(f"  load={r.load:<5} accepted={r.accepted:.4f}  [{sat}]")
    knee = out.saturation_points(fidelity="flow")[spec.name]
    print(f"saturation knee: {knee if knee is not None else '> max load'}")

    # Bottleneck link sets: re-solve the knee (or worst) point with the
    # raw model API, which keeps the full allocation around.
    probe = knee if knee is not None else loads[-1]
    topo = fabric.resolve_topology()
    params = FlowParams()
    src, dst, rate = pattern_demands(topo, "uniform", probe,
                                     args.terminals, params, None)
    sol = solve_flows(topo, args.routing, src, dst, rate, params=params)
    print(f"\nbottleneck links at load {probe} "
          f"(top {args.top} of {topo.num_links} wired):")
    for b in sol.bottleneck_links(top=args.top):
        print(f"  switch {b['switch']:>5} port {b['port']:>2} -> "
              f"switch {b['neighbor']:>5}  "
              f"utilization={b['utilization']:.3f} "
              f"(served {b['served']:.3f} of {b['capacity']:.3f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
