"""End-to-end training driver: fault-tolerant loop on the synthetic
pipeline with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py                 # quick demo
    PYTHONPATH=src python examples/train_lm.py --full --steps 300
    PYTHONPATH=src python examples/train_lm.py --fail-at 40    # crash+resume

``--full`` trains the ~50M-parameter lacin-demo config (8L x 512d, tied
32768 vocab); the default is its reduced variant for a fast CPU demo.
The loop is crash-only: ``--fail-at`` injects a failure at that step and
the run resumes from the latest atomic checkpoint.
"""
import argparse

from repro.data.pipeline import DataConfig
from repro.models import get_config
from repro.optim import OptConfig
from repro.runtime.loop import LoopConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lacin-demo")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (not the reduced smoke size)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="results/example_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (tests restart)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    print(f"training {cfg.name}: ~{cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, repeat_p=0.7)
    loop = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=5,
                      fail_at_steps=(args.fail_at,) if args.fail_at else ())
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                    total_steps=args.steps)
    report = run_training(cfg, opt, loop, data)
    print(f"steps run: {report.steps_run}, restarts: {report.restarts}, "
          f"restored from: {report.restored_from}")
    for s, l in report.losses:
        print(f"  step {s:4d}  loss {l:.4f}")
    first, last = report.losses[0][1], report.losses[-1][1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
