"""Quickstart: the paper in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. Builds the three CIN instances of Figure 2 and verifies their structure.
2. Routes packets table-free (§3) and prints the LACIN layout stats (§4).
3. Prints the 16^3 HyperX deployment (§5).
4. Runs a tiny LM train step whose MoE dispatch uses the XOR 1-factor
   schedule (single device; see examples/multidev_collectives.py for the
   multi-device demonstration).
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (lacin_total_wire_length, make_schedule, port_matrix,
                        route_packet, swap_to_lacin_ratio, table1,
                        verify_instance)
from repro.core.hyperx import paper_16cubed


def main():
    print("=== Figure 2: P matrices (N=8), from the instance registry ===")
    from repro import fabric
    for inst in fabric.instance_names():     # incl. the registered 'mirror'
        if not fabric.get_instance(inst).supports(8):
            continue
        P = port_matrix(inst, 8)
        rep = verify_instance(inst, 8)
        print(f"\n{inst} (isoport={rep['isoport']}):\n{P}")

    print("\n=== §3 minimal routing: computer (3,5) -> (6,2), XOR CIN-8 ===")
    print("hops (switch, out-port):", route_packet("xor", 8, (3, 5), (6, 2)))

    print("\n=== §4 LACIN layout (Table 1) ===")
    for r in table1(n=256):
        print(f"  {r.instance:7s} isoport={str(r.isoport):5s} "
              f"wire_norm={r.wire_length_norm:.3f} "
              f"routing_cost=+{r.routing_cost} vs XOR")
    print(f"  total LACIN wire length N=16: {lacin_total_wire_length(16)} "
          f"(= (16^3-16)/6)")

    print("\n=== §5 the 16x16x16 HyperX, XOR-LACIN wired ===")
    for k, v in paper_16cubed().report().items():
        print(f"  {k} = {v}")

    print("\n=== §2 as a collective schedule (mesh axis of 16) ===")
    s = make_schedule("auto", 16)
    print(f"  instance={s.instance} steps={s.num_steps} "
          f"matching/step={s.is_matching_per_step()} "
          f"contention_free={s.is_contention_free()}")
    print(f"  step 3 pairs: {s.perm(3)[:4]} ...")

    print("\n=== tiny LM train step (lacin-demo, 1 device) ===")
    from repro.models import get_config
    from repro.optim import OptConfig
    from repro.runtime.trainer import init_train_state, make_train_step
    from repro.models.layers import AxisRules

    cfg = get_config("lacin-demo").reduced()
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, AxisRules(), OptConfig(lr=1e-3)))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)), jnp.int32)
    for i in range(3):
        state, metrics = step(state, {"tokens": tok, "labels": tok})
        print(f"  step {i}: loss={float(metrics['loss']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.3f}")
    print("done.")


if __name__ == "__main__":
    main()
